//! Model registry — the serving layer's warm per-model state.
//!
//! One [`ModelEntry`] per configured `<model>/<cfg>` spec: a fully warmed
//! [`Session`] (parameters loaded or pre-trained, activation ranges
//! initialized) plus the AppMul [`Library`] covering its manifest, loaded
//! through the PR 3 artifact store when one is enabled — so a restarted
//! daemon skips both training and library characterization.
//!
//! Entries are immutable once warmed: every request handler works through
//! `&Session` (`evaluate` / `evaluate_with` never mutate session state),
//! which is what lets the batcher score concurrent requests against one
//! shared entry without locks.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::appmul::{AppMul, Library};
use crate::pipeline::{self, FamesConfig, ParamsSource, Session};
use crate::runtime::Runtime;
use crate::tensor::Tensor;

/// One warmed model: routing key, session, candidate library.
pub struct ModelEntry {
    /// Routing key, `<model>/<cfg>`.
    pub key: String,
    pub session: Session,
    pub library: Library,
    /// Library stage cache outcome (`Some(true)` = store hit).
    pub lib_hit: Option<bool>,
    /// Where the trained parameters came from (state file / store /
    /// trained here) — `Store` on a fresh root means warm handoff worked.
    pub params_source: ParamsSource,
    /// Wall-clock spent warming this entry (train/load + ranges + library).
    pub warm_secs: f64,
}

impl ModelEntry {
    /// Per-layer candidate lists in `Library::for_bits` order — the index
    /// space every wire `selection` refers to.
    pub fn choices(&self) -> Vec<Vec<&AppMul>> {
        self.session
            .art
            .manifest
            .layers
            .iter()
            .map(|l| self.library.for_bits(l.a_bits, l.w_bits))
            .collect()
    }

    /// Resolve a wire selection (per-layer candidate indices) to AppMuls.
    pub fn resolve_selection(&self, picks: &[usize]) -> Result<Vec<&AppMul>> {
        let layers = &self.session.art.manifest.layers;
        ensure!(
            picks.len() == layers.len(),
            "selection has {} picks, model '{}' has {} layers",
            picks.len(),
            self.key,
            layers.len()
        );
        layers
            .iter()
            .zip(picks)
            .map(|(l, &i)| {
                let muls = self.library.for_bits(l.a_bits, l.w_bits);
                ensure!(
                    i < muls.len(),
                    "layer {}: pick {i} out of range ({} candidates)",
                    l.name,
                    muls.len()
                );
                Ok(muls[i])
            })
            .collect()
    }

    /// E-tensor list for a wire selection (the `evaluate_with` input).
    pub fn selection_tensors(&self, picks: &[usize]) -> Result<Vec<Tensor>> {
        Ok(self.resolve_selection(picks)?.iter().map(|am| am.error_tensor()).collect())
    }
}

/// All loaded models, keyed by `<model>/<cfg>`.
pub struct Registry {
    entries: BTreeMap<String, Arc<ModelEntry>>,
}

impl Registry {
    /// Warm every configured model spec. Specs are `<model>/<cfg>` (a `:`
    /// separator is also accepted); each is opened against `base` with the
    /// model/cfg fields swapped in, so `base` carries the artifact root,
    /// seed, worker count, training and cache knobs for all of them.
    pub fn open(rt: Arc<Runtime>, base: &FamesConfig, specs: &[String]) -> Result<Registry> {
        ensure!(!specs.is_empty(), "no models configured (pass models=<model>/<cfg>[,...])");
        let mut entries = BTreeMap::new();
        for spec in specs {
            let (model, cfg_name) = split_spec(spec)?;
            let key = format!("{model}/{cfg_name}");
            if entries.contains_key(&key) {
                bail!("model '{key}' configured twice");
            }
            let cfg = FamesConfig {
                model: model.to_string(),
                cfg: cfg_name.to_string(),
                ..base.clone()
            };
            let t0 = Instant::now();
            let (session, warm) = pipeline::warm_session_report(rt.clone(), &cfg)
                .with_context(|| format!("warming model '{key}'"))?;
            let store = cfg.store();
            let prep =
                pipeline::prepare_library(&session.art.manifest, cfg.seed, store.as_ref(), cfg.jobs)
                    .with_context(|| format!("preparing library for '{key}'"))?;
            entries.insert(
                key.clone(),
                Arc::new(ModelEntry {
                    key,
                    session,
                    library: prep.library,
                    lib_hit: prep.hit,
                    params_source: warm.params,
                    warm_secs: t0.elapsed().as_secs_f64(),
                }),
            );
        }
        Ok(Registry { entries })
    }

    /// Route a request to a model. `None` is allowed only when exactly one
    /// model is loaded (the single-model convenience).
    pub fn get(&self, key: Option<&str>) -> Result<&Arc<ModelEntry>> {
        match key {
            Some(k) => self.entries.get(k).with_context(|| {
                format!("unknown model '{k}' (loaded: {})", self.keys().join(", "))
            }),
            None if self.entries.len() == 1 => Ok(self.entries.values().next().unwrap()),
            None => bail!(
                "request names no model and {} are loaded — pass \"model\":\"<model>/<cfg>\"",
                self.entries.len()
            ),
        }
    }

    pub fn keys(&self) -> Vec<String> {
        self.entries.keys().cloned().collect()
    }

    pub fn entries(&self) -> impl Iterator<Item = &Arc<ModelEntry>> {
        self.entries.values()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Split `<model>/<cfg>` (or `<model>:<cfg>`).
fn split_spec(spec: &str) -> Result<(&str, &str)> {
    let (m, c) = spec
        .split_once('/')
        .or_else(|| spec.split_once(':'))
        .with_context(|| format!("model spec '{spec}' must be <model>/<cfg>"))?;
    ensure!(!m.is_empty() && !c.is_empty(), "model spec '{spec}' must be <model>/<cfg>");
    Ok((m, c))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_splitting() {
        assert_eq!(split_spec("resnet8/w4a4").unwrap(), ("resnet8", "w4a4"));
        assert_eq!(split_spec("vgg11:w2a2").unwrap(), ("vgg11", "w2a2"));
        assert!(split_spec("resnet8").is_err());
        assert!(split_spec("/w4a4").is_err());
        assert!(split_spec("resnet8/").is_err());
    }
}
