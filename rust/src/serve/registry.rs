//! Model registry — the serving layer's warm per-model state.
//!
//! One [`ModelEntry`] per configured `<model>/<cfg>` spec: a fully warmed
//! [`Session`] (parameters loaded or pre-trained, activation ranges
//! initialized) plus the AppMul [`Library`] covering its manifest, loaded
//! through the PR 3 artifact store when one is enabled — so a restarted
//! daemon skips both training and library characterization.
//!
//! The **immutable** half of an entry (session, library, fingerprint
//! anchors) never changes once warmed: every request handler works
//! through `&Session` (`evaluate` / `evaluate_with` /
//! `evaluate_operating_point` never mutate session state), which is what
//! lets the batcher score concurrent requests against one shared entry
//! without locks. The **mobile** half — the entry's
//! [`ActiveSelection`] operating point and the config it derives from —
//! sits behind its own locks and is swapped atomically by `reconfigure`;
//! the dispatcher snapshots it once per wave, so in-flight requests
//! always finish under the selection they started with.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::appmul::{AppMul, Library};
use crate::pipeline::{self, ActiveSelection, FamesConfig, ParamsSource, ParetoFront, Session};
use crate::runtime::Runtime;
use crate::store::Fingerprint;
use crate::tensor::Tensor;

/// One warmed model: routing key, session, candidate library, and the
/// swappable operating point.
pub struct ModelEntry {
    /// Routing key, `<model>/<cfg>`.
    pub key: String,
    pub session: Session,
    pub library: Library,
    /// Library stage cache outcome (`Some(true)` = store hit).
    pub lib_hit: Option<bool>,
    /// Content fingerprint of `library` — the immutable upstream anchor
    /// every reconfigure chains its stage fingerprints from.
    pub lib_fp: Fingerprint,
    /// Hash of the model's `manifest.json` (estimate fingerprint input).
    pub manifest_hash: u64,
    /// Content hash of the trained parameters in `session`.
    pub params_hash: u64,
    /// Where the trained parameters came from (state file / store /
    /// trained here) — `Store` on a fresh root means warm handoff worked.
    pub params_source: ParamsSource,
    /// Wall-clock spent warming this entry (train/load + ranges + library).
    pub warm_secs: f64,
    /// This entry's effective config: the serve base with the entry's
    /// model/cfg swapped in, plus every applied `reconfigure` delta.
    /// Held locked across a reconfigure so concurrent deltas serialize.
    pub cfg: Mutex<FamesConfig>,
    /// The active operating point; `None` serves the plain warmed session
    /// (byte-identical to the pre-adaptive daemon). Swapped whole — the
    /// dispatcher snapshots the `Arc` once per wave.
    pub active: RwLock<Option<Arc<ActiveSelection>>>,
    /// Precomputed Pareto front (`pareto=` grid); `None` when no grid is
    /// configured.
    pub pareto: Option<Arc<ParetoFront>>,
    /// Reconfigures answered from the in-memory front.
    pub pareto_hits: AtomicU64,
    /// Reconfigures that fell through to the store or a fresh activation.
    pub pareto_misses: AtomicU64,
    /// Operating-point swaps applied to this entry.
    pub swaps: AtomicU64,
}

impl ModelEntry {
    /// Per-layer candidate lists in `Library::for_bits` order — the index
    /// space every wire `selection` refers to.
    pub fn choices(&self) -> Vec<Vec<&AppMul>> {
        self.session
            .art
            .manifest
            .layers
            .iter()
            .map(|l| self.library.for_bits(l.a_bits, l.w_bits))
            .collect()
    }

    /// Resolve a wire selection (per-layer candidate indices) to AppMuls.
    pub fn resolve_selection(&self, picks: &[usize]) -> Result<Vec<&AppMul>> {
        let layers = &self.session.art.manifest.layers;
        ensure!(
            picks.len() == layers.len(),
            "selection has {} picks, model '{}' has {} layers",
            picks.len(),
            self.key,
            layers.len()
        );
        layers
            .iter()
            .zip(picks)
            .map(|(l, &i)| {
                let muls = self.library.for_bits(l.a_bits, l.w_bits);
                ensure!(
                    i < muls.len(),
                    "layer {}: pick {i} out of range ({} candidates)",
                    l.name,
                    muls.len()
                );
                Ok(muls[i])
            })
            .collect()
    }

    /// E-tensor list for a wire selection (the `evaluate_with` input).
    pub fn selection_tensors(&self, picks: &[usize]) -> Result<Vec<Tensor>> {
        Ok(self.resolve_selection(picks)?.iter().map(|am| am.error_tensor()).collect())
    }

    /// Install a new operating point. The write is atomic; it takes effect
    /// at the next dispatcher wave snapshot, so every request in a wave
    /// runs under exactly one selection.
    pub fn swap_active(&self, act: Arc<ActiveSelection>) {
        *self.active.write().unwrap() = Some(act);
        self.swaps.fetch_add(1, Ordering::Relaxed);
    }

    /// The current operating-point fingerprint, `None` when this entry
    /// serves the plain warmed session.
    pub fn active_fingerprint(&self) -> Option<Fingerprint> {
        self.active.read().unwrap().as_ref().map(|a| a.fingerprint)
    }

    /// The current operating-point handle.
    pub fn active_selection(&self) -> Option<Arc<ActiveSelection>> {
        self.active.read().unwrap().clone()
    }
}

/// All loaded models, keyed by `<model>/<cfg>`.
pub struct Registry {
    entries: BTreeMap<String, Arc<ModelEntry>>,
}

impl Registry {
    /// Warm every configured model spec. Specs are `<model>/<cfg>` (a `:`
    /// separator is also accepted); each is opened against `base` with the
    /// model/cfg fields swapped in, so `base` carries the artifact root,
    /// seed, worker count, training and cache knobs for all of them.
    pub fn open(rt: Arc<Runtime>, base: &FamesConfig, specs: &[String]) -> Result<Registry> {
        ensure!(!specs.is_empty(), "no models configured (pass models=<model>/<cfg>[,...])");
        let mut entries = BTreeMap::new();
        for spec in specs {
            let (model, cfg_name) = split_spec(spec)?;
            let key = format!("{model}/{cfg_name}");
            if entries.contains_key(&key) {
                bail!("model '{key}' configured twice");
            }
            let cfg = FamesConfig {
                model: model.to_string(),
                cfg: cfg_name.to_string(),
                ..base.clone()
            };
            let t0 = Instant::now();
            let (mut session, warm) = pipeline::warm_session_report(rt.clone(), &cfg)
                .with_context(|| format!("warming model '{key}'"))?;
            let store = cfg.store();
            let prep =
                pipeline::prepare_library(&session.art.manifest, cfg.seed, store.as_ref(), cfg.jobs)
                    .with_context(|| format!("preparing library for '{key}'"))?;
            let manifest_hash =
                crate::util::hash::hash_file(session.art.dir.join("manifest.json"))?;
            let params_hash = session.params.content_hash();
            // with a pareto grid configured, precompute the front and put
            // the configured budget live; without one, serve the plain
            // warmed session (byte-identical to the pre-adaptive daemon)
            let (pareto, active) = if cfg.pareto_grid.is_empty() {
                (None, None)
            } else {
                let sweep =
                    pipeline::active::sweep_pareto(&mut session, &prep.library, prep.fingerprint, &cfg)
                        .with_context(|| format!("sweeping pareto front for '{key}'"))?;
                let front = Arc::new(sweep.front);
                let est_fp = pipeline::estimate_fingerprint(
                    &cfg,
                    prep.fingerprint,
                    manifest_hash,
                    params_hash,
                );
                let cal_fp =
                    pipeline::calibrate_fingerprint(&cfg, pipeline::select_fingerprint(&cfg, est_fp));
                let act = match front.lookup_fp(cal_fp) {
                    Some(p) => p.to_active(&prep.library, &session.art.manifest)?,
                    None => {
                        pipeline::active::activate(&mut session, &prep.library, prep.fingerprint, &cfg)?
                            .selection
                    }
                };
                (Some(front), Some(Arc::new(act)))
            };
            entries.insert(
                key.clone(),
                Arc::new(ModelEntry {
                    key,
                    session,
                    library: prep.library,
                    lib_hit: prep.hit,
                    lib_fp: prep.fingerprint,
                    manifest_hash,
                    params_hash,
                    params_source: warm.params,
                    warm_secs: t0.elapsed().as_secs_f64(),
                    cfg: Mutex::new(cfg),
                    active: RwLock::new(active),
                    pareto,
                    pareto_hits: AtomicU64::new(0),
                    pareto_misses: AtomicU64::new(0),
                    swaps: AtomicU64::new(0),
                }),
            );
        }
        Ok(Registry { entries })
    }

    /// Route a request to a model. `None` is allowed only when exactly one
    /// model is loaded (the single-model convenience).
    pub fn get(&self, key: Option<&str>) -> Result<&Arc<ModelEntry>> {
        match key {
            Some(k) => self.entries.get(k).with_context(|| {
                format!("unknown model '{k}' (loaded: {})", self.keys().join(", "))
            }),
            None if self.entries.len() == 1 => Ok(self.entries.values().next().unwrap()),
            None => bail!(
                "request names no model and {} are loaded — pass \"model\":\"<model>/<cfg>\"",
                self.entries.len()
            ),
        }
    }

    pub fn keys(&self) -> Vec<String> {
        self.entries.keys().cloned().collect()
    }

    /// Snapshot every model's active operating point. The dispatcher takes
    /// one snapshot per wave, which pins all requests in that wave to the
    /// selection in force when the wave started — the wave-boundary
    /// atomicity contract of `reconfigure`.
    pub fn active_snapshot(&self) -> BTreeMap<String, Arc<ActiveSelection>> {
        let mut map = BTreeMap::new();
        for (k, e) in &self.entries {
            if let Some(a) = e.active.read().unwrap().as_ref() {
                map.insert(k.clone(), a.clone());
            }
        }
        map
    }

    pub fn entries(&self) -> impl Iterator<Item = &Arc<ModelEntry>> {
        self.entries.values()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Split `<model>/<cfg>` (or `<model>:<cfg>`).
fn split_spec(spec: &str) -> Result<(&str, &str)> {
    let (m, c) = spec
        .split_once('/')
        .or_else(|| spec.split_once(':'))
        .with_context(|| format!("model spec '{spec}' must be <model>/<cfg>"))?;
    ensure!(!m.is_empty() && !c.is_empty(), "model spec '{spec}' must be <model>/<cfg>");
    Ok((m, c))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_splitting() {
        assert_eq!(split_spec("resnet8/w4a4").unwrap(), ("resnet8", "w4a4"));
        assert_eq!(split_spec("vgg11:w2a2").unwrap(), ("vgg11", "w2a2"));
        assert!(split_spec("resnet8").is_err());
        assert!(split_spec("/w4a4").is_err());
        assert!(split_spec("resnet8/").is_err());
    }
}
