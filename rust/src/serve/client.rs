//! Blocking NDJSON client for `fames serve` — used by the smoke tests, the
//! serve bench, and as the embedding reference implementation.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::{ensure, Context, Result};

use crate::json::Json;

/// One connection to a serve daemon.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to fames serve at {addr}"))?;
        let _ = stream.set_nodelay(true);
        let writer = stream.try_clone().context("cloning client stream")?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Fire one request line without waiting (pipelining).
    pub fn send(&mut self, req: &Json) -> Result<()> {
        let mut line = req.compact();
        line.push('\n');
        self.writer.write_all(line.as_bytes()).context("writing request")
    }

    /// Read one response line.
    pub fn recv(&mut self) -> Result<Json> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).context("reading response")?;
        ensure!(n > 0, "connection closed by server");
        Json::parse(line.trim()).context("response is not valid JSON")
    }

    /// One request, one response (single outstanding call).
    pub fn call(&mut self, req: &Json) -> Result<Json> {
        self.send(req)?;
        self.recv()
    }

    /// Pipeline several requests and return the responses matched back to
    /// request order by `id` (waves may interleave responses).
    pub fn call_many(&mut self, reqs: &[Json]) -> Result<Vec<Json>> {
        for r in reqs {
            self.send(r)?;
        }
        let mut by_id: BTreeMap<i64, Json> = BTreeMap::new();
        for _ in reqs {
            let resp = self.recv()?;
            let id = resp.get("id")?.as_i64()?;
            by_id.insert(id, resp);
        }
        reqs.iter()
            .map(|r| {
                let id = r.get("id")?.as_i64()?;
                by_id.remove(&id).with_context(|| format!("no response for id {id}"))
            })
            .collect()
    }

    /// `result` payload of a successful response; `Err` with the server's
    /// message on `ok: false`.
    pub fn expect_ok(resp: &Json) -> Result<&Json> {
        if resp.get("ok")?.as_bool()? {
            resp.get("result")
        } else {
            anyhow::bail!(
                "server error (id {}): {}",
                resp.get("id")?.as_i64().unwrap_or(-1),
                resp.get("error")?.as_str().unwrap_or("?")
            )
        }
    }

    /// Convenience: request a clean shutdown and return the ack payload.
    pub fn shutdown(&mut self, id: i64) -> Result<Json> {
        let resp = self.call(&Json::obj().with("id", id).with("op", "shutdown"))?;
        Self::expect_ok(&resp).map(|j| j.clone())
    }
}
