//! Blocking NDJSON client for `fames serve` — used by the smoke tests, the
//! serve bench, and as the embedding reference implementation.

use std::collections::{BTreeMap, BTreeSet};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{ensure, Context, Result};

use crate::json::Json;

/// Per-request verdict from [`Client::call_many_outcomes`]: unlike
/// [`Client::call_many`], overload and error responses surface here per
/// id instead of failing the whole pipeline.
#[derive(Clone, Debug)]
pub enum Outcome {
    /// `ok:true` — the `result` payload.
    Ok(Json),
    /// `ok:false` — the server's message; `shed` marks an explicit,
    /// retry-able overload refusal rather than a request defect.
    Err { error: String, shed: bool },
    /// The connection died (or the response was unmatchable) before this
    /// request was answered.
    Lost,
}

impl Outcome {
    /// Explicitly shed by admission control — safe to retry.
    pub fn is_shed(&self) -> bool {
        matches!(self, Outcome::Err { shed: true, .. })
    }
}

/// Classify one response envelope.
fn outcome_of(resp: &Json) -> Outcome {
    if resp.get("ok").and_then(|j| j.as_bool()).unwrap_or(false) {
        match resp.get("result") {
            Ok(r) => Outcome::Ok(r.clone()),
            Err(_) => Outcome::Err { error: "ok response without result".to_string(), shed: false },
        }
    } else {
        let error = resp
            .get("error")
            .ok()
            .and_then(|j| j.as_str().ok())
            .unwrap_or("?")
            .to_string();
        let shed = resp.get("shed").and_then(|j| j.as_bool()).unwrap_or(false);
        Outcome::Err { error, shed }
    }
}

/// One connection to a serve daemon.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to fames serve at {addr}"))?;
        let _ = stream.set_nodelay(true);
        let writer = stream.try_clone().context("cloning client stream")?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Fire one request line without waiting (pipelining).
    pub fn send(&mut self, req: &Json) -> Result<()> {
        let mut line = req.compact();
        line.push('\n');
        self.writer.write_all(line.as_bytes()).context("writing request")
    }

    /// Read one response line.
    pub fn recv(&mut self) -> Result<Json> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).context("reading response")?;
        ensure!(n > 0, "connection closed by server");
        Json::parse(line.trim()).context("response is not valid JSON")
    }

    /// One request, one response (single outstanding call).
    pub fn call(&mut self, req: &Json) -> Result<Json> {
        self.send(req)?;
        self.recv()
    }

    /// Pipeline several requests and return the responses matched back to
    /// request order by `id` (waves may interleave responses).
    pub fn call_many(&mut self, reqs: &[Json]) -> Result<Vec<Json>> {
        for r in reqs {
            self.send(r)?;
        }
        let mut by_id: BTreeMap<i64, Json> = BTreeMap::new();
        for _ in reqs {
            let resp = self.recv()?;
            let id = resp.get("id")?.as_i64()?;
            by_id.insert(id, resp);
        }
        reqs.iter()
            .map(|r| {
                let id = r.get("id")?.as_i64()?;
                by_id.remove(&id).with_context(|| format!("no response for id {id}"))
            })
            .collect()
    }

    /// Pipeline several requests and return one [`Outcome`] per request,
    /// in request order. Never fails as a whole: sheds and server errors
    /// come back per id, a dead connection marks the unanswered tail
    /// [`Outcome::Lost`], and a connection-level shed (the gate's `id:-1`
    /// refusal line) marks every unanswered request shed so callers can
    /// retry.
    pub fn call_many_outcomes(&mut self, reqs: &[Json]) -> Vec<Outcome> {
        let mut sent = 0usize;
        for r in reqs {
            if self.send(r).is_err() {
                break; // answered prefix still drains below
            }
            sent += 1;
        }
        let want: Vec<Option<i64>> =
            reqs.iter().map(|r| r.get("id").and_then(|j| j.as_i64()).ok()).collect();
        let want_set: BTreeSet<i64> = want.iter().flatten().copied().collect();
        let mut by_id: BTreeMap<i64, Outcome> = BTreeMap::new();
        let mut conn_shed: Option<String> = None;
        for _ in 0..sent {
            let Ok(resp) = self.recv() else { break };
            let id = resp.get("id").and_then(|j| j.as_i64()).unwrap_or(i64::MIN);
            if want_set.contains(&id) {
                by_id.insert(id, outcome_of(&resp));
            } else if let Outcome::Err { error, shed: true } = outcome_of(&resp) {
                // the admission gate answers with one id:-1 shed line and
                // closes — it refuses the whole connection, not one id
                conn_shed = Some(error);
            }
        }
        want.into_iter()
            .map(|id| match id.and_then(|id| by_id.remove(&id)) {
                Some(o) => o,
                None => match &conn_shed {
                    Some(error) => Outcome::Err { error: error.clone(), shed: true },
                    None => Outcome::Lost,
                },
            })
            .collect()
    }

    /// [`Client::call_many_outcomes`], retrying each shed request once
    /// after `backoff` — the reference polite-client loop for overload:
    /// back off, resend only what was shed, splice results back in
    /// request order.
    pub fn call_many_retry_shed(&mut self, reqs: &[Json], backoff: Duration) -> Vec<Outcome> {
        let mut outcomes = self.call_many_outcomes(reqs);
        let retry_idx: Vec<usize> = outcomes
            .iter()
            .enumerate()
            .filter(|(_, o)| o.is_shed())
            .map(|(i, _)| i)
            .collect();
        if retry_idx.is_empty() {
            return outcomes;
        }
        std::thread::sleep(backoff);
        let retry_reqs: Vec<Json> = retry_idx.iter().map(|&i| reqs[i].clone()).collect();
        let retried = self.call_many_outcomes(&retry_reqs);
        for (slot, out) in retry_idx.into_iter().zip(retried) {
            outcomes[slot] = out;
        }
        outcomes
    }

    /// `result` payload of a successful response; `Err` with the server's
    /// message on `ok: false`.
    pub fn expect_ok(resp: &Json) -> Result<&Json> {
        if resp.get("ok")?.as_bool()? {
            resp.get("result")
        } else {
            anyhow::bail!(
                "server error (id {}): {}",
                resp.get("id")?.as_i64().unwrap_or(-1),
                resp.get("error")?.as_str().unwrap_or("?")
            )
        }
    }

    /// Convenience: request a clean shutdown and return the ack payload.
    pub fn shutdown(&mut self, id: i64) -> Result<Json> {
        let resp = self.call(&Json::obj().with("id", id).with("op", "shutdown"))?;
        Self::expect_ok(&resp).map(|j| j.clone())
    }
}
