//! Blocking NDJSON client for `fames serve` — used by the smoke tests, the
//! serve bench, and as the embedding reference implementation.

use std::collections::{BTreeMap, BTreeSet};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{ensure, Context, Result};

use crate::json::Json;
use crate::util::hash::Fnv64;

/// Most retry rounds [`Client::call_many_retry_shed`] will spend on
/// requests the server keeps shedding; past it, the surviving shed
/// outcomes are returned to the caller as-is.
pub const SHED_RETRY_BUDGET: u32 = 4;

/// Upper bound on any one backoff sleep, jitter included — the
/// exponential schedule stops doubling here.
pub const SHED_BACKOFF_CAP: Duration = Duration::from_secs(2);

/// Backoff before retry round `attempt` (0-based): `base << attempt`
/// capped at [`SHED_BACKOFF_CAP`], plus a deterministic jitter in
/// `[0, base/2)` hashed from the shed request ids and the attempt number.
/// Jitter keeps a fleet of polite clients that were shed together from
/// resending in lockstep, and hashing (FNV, no `rand`) keeps the client
/// bit-reproducible: the same shed set retries on the same schedule.
fn shed_backoff(base: Duration, attempt: u32, shed_ids: &[i64]) -> Duration {
    let exp = base.saturating_mul(1u32 << attempt.min(16)).min(SHED_BACKOFF_CAP);
    let mut h = Fnv64::new();
    h.write_str("fames-shed-backoff");
    h.write_u64(attempt as u64);
    for &id in shed_ids {
        h.write_i64(id);
    }
    let half = (base.as_nanos() as u64 / 2).max(1);
    let jitter = Duration::from_nanos(h.finish() % half);
    exp.saturating_add(jitter).min(SHED_BACKOFF_CAP)
}

/// Per-request verdict from [`Client::call_many_outcomes`]: unlike
/// [`Client::call_many`], overload and error responses surface here per
/// id instead of failing the whole pipeline.
#[derive(Clone, Debug)]
pub enum Outcome {
    /// `ok:true` — the `result` payload.
    Ok(Json),
    /// `ok:false` — the server's message; `shed` marks an explicit,
    /// retry-able overload refusal rather than a request defect.
    Err { error: String, shed: bool },
    /// The connection died (or the response was unmatchable) before this
    /// request was answered.
    Lost,
}

impl Outcome {
    /// Explicitly shed by admission control — safe to retry.
    pub fn is_shed(&self) -> bool {
        matches!(self, Outcome::Err { shed: true, .. })
    }
}

/// Classify one response envelope.
fn outcome_of(resp: &Json) -> Outcome {
    if resp.get("ok").and_then(|j| j.as_bool()).unwrap_or(false) {
        match resp.get("result") {
            Ok(r) => Outcome::Ok(r.clone()),
            Err(_) => Outcome::Err { error: "ok response without result".to_string(), shed: false },
        }
    } else {
        let error = resp
            .get("error")
            .ok()
            .and_then(|j| j.as_str().ok())
            .unwrap_or("?")
            .to_string();
        let shed = resp.get("shed").and_then(|j| j.as_bool()).unwrap_or(false);
        Outcome::Err { error, shed }
    }
}

/// One connection to a serve daemon.
pub struct Client {
    addr: String,
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to fames serve at {addr}"))?;
        let _ = stream.set_nodelay(true);
        let writer = stream.try_clone().context("cloning client stream")?;
        Ok(Client { addr: addr.to_string(), reader: BufReader::new(stream), writer })
    }

    /// Replace a dead connection with a fresh one to the same address —
    /// the [`Outcome::Lost`] retry path (a router stays up across shard
    /// restarts; only this client↔router socket needs redialing).
    pub fn reconnect(&mut self) -> Result<()> {
        *self = Client::connect(&self.addr)?;
        Ok(())
    }

    /// Fire one request line without waiting (pipelining).
    pub fn send(&mut self, req: &Json) -> Result<()> {
        let mut line = req.compact();
        line.push('\n');
        self.writer.write_all(line.as_bytes()).context("writing request")
    }

    /// Read one response line.
    pub fn recv(&mut self) -> Result<Json> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).context("reading response")?;
        ensure!(n > 0, "connection closed by server");
        Json::parse(line.trim()).context("response is not valid JSON")
    }

    /// One request, one response (single outstanding call).
    pub fn call(&mut self, req: &Json) -> Result<Json> {
        self.send(req)?;
        self.recv()
    }

    /// Pipeline several requests and return the responses matched back to
    /// request order by `id` (waves may interleave responses).
    pub fn call_many(&mut self, reqs: &[Json]) -> Result<Vec<Json>> {
        for r in reqs {
            self.send(r)?;
        }
        let mut by_id: BTreeMap<i64, Json> = BTreeMap::new();
        for _ in reqs {
            let resp = self.recv()?;
            let id = resp.get("id")?.as_i64()?;
            by_id.insert(id, resp);
        }
        reqs.iter()
            .map(|r| {
                let id = r.get("id")?.as_i64()?;
                by_id.remove(&id).with_context(|| format!("no response for id {id}"))
            })
            .collect()
    }

    /// Pipeline several requests and return one [`Outcome`] per request,
    /// in request order. Never fails as a whole: sheds and server errors
    /// come back per id, a dead connection marks the unanswered tail
    /// [`Outcome::Lost`], and a connection-level shed (the gate's `id:-1`
    /// refusal line) marks every unanswered request shed so callers can
    /// retry.
    pub fn call_many_outcomes(&mut self, reqs: &[Json]) -> Vec<Outcome> {
        let mut sent = 0usize;
        for r in reqs {
            if self.send(r).is_err() {
                break; // answered prefix still drains below
            }
            sent += 1;
        }
        let want: Vec<Option<i64>> =
            reqs.iter().map(|r| r.get("id").and_then(|j| j.as_i64()).ok()).collect();
        let want_set: BTreeSet<i64> = want.iter().flatten().copied().collect();
        let mut by_id: BTreeMap<i64, Outcome> = BTreeMap::new();
        let mut conn_shed: Option<String> = None;
        for _ in 0..sent {
            let Ok(resp) = self.recv() else { break };
            let id = resp.get("id").and_then(|j| j.as_i64()).unwrap_or(i64::MIN);
            if want_set.contains(&id) {
                by_id.insert(id, outcome_of(&resp));
            } else if let Outcome::Err { error, shed: true } = outcome_of(&resp) {
                // the admission gate answers with one id:-1 shed line and
                // closes — it refuses the whole connection, not one id
                conn_shed = Some(error);
            }
        }
        want.into_iter()
            .map(|id| match id.and_then(|id| by_id.remove(&id)) {
                Some(o) => o,
                None => match &conn_shed {
                    Some(error) => Outcome::Err { error: error.clone(), shed: true },
                    None => Outcome::Lost,
                },
            })
            .collect()
    }

    /// [`Client::call_many_outcomes`], retrying shed requests — the
    /// reference polite-client loop for overload: back off, resend only
    /// what was shed, splice results back in request order. Backoff is
    /// exponential from `base` with deterministic per-attempt jitter
    /// (see [`shed_backoff`]), and the loop gives up after
    /// [`SHED_RETRY_BUDGET`] rounds, returning the surviving shed
    /// outcomes so the caller sees exactly what the server refused.
    ///
    /// [`Outcome::Lost`] is *not* terminal: once per call, lost requests
    /// are retried too, on a fresh connection to the same address and
    /// within the same capped-backoff budget — a fleet router that failed
    /// over mid-wave (or a shard finishing a rolling restart) answers the
    /// redial. Still lost after that one extra dial ⇒ returned as `Lost`.
    pub fn call_many_retry_shed(&mut self, reqs: &[Json], base: Duration) -> Vec<Outcome> {
        let mut outcomes = self.call_many_outcomes(reqs);
        let mut lost_retry_used = false;
        for attempt in 0..SHED_RETRY_BUDGET {
            let retry_lost = !lost_retry_used
                && outcomes.iter().any(|o| matches!(o, Outcome::Lost));
            let retry_idx: Vec<usize> = outcomes
                .iter()
                .enumerate()
                .filter(|(_, o)| o.is_shed() || (retry_lost && matches!(o, Outcome::Lost)))
                .map(|(i, _)| i)
                .collect();
            if retry_idx.is_empty() {
                break;
            }
            if retry_lost {
                // the old socket is dead (or desynced); retrying Lost ids
                // on it would only lose them again
                lost_retry_used = true;
                if self.reconnect().is_err() {
                    break;
                }
            }
            let shed_ids: Vec<i64> = retry_idx
                .iter()
                .filter_map(|&i| reqs[i].get("id").and_then(|j| j.as_i64()).ok())
                .collect();
            std::thread::sleep(shed_backoff(base, attempt, &shed_ids));
            let retry_reqs: Vec<Json> = retry_idx.iter().map(|&i| reqs[i].clone()).collect();
            let retried = self.call_many_outcomes(&retry_reqs);
            for (slot, out) in retry_idx.into_iter().zip(retried) {
                outcomes[slot] = out;
            }
        }
        outcomes
    }

    /// `result` payload of a successful response; `Err` with the server's
    /// message on `ok: false`.
    pub fn expect_ok(resp: &Json) -> Result<&Json> {
        if resp.get("ok")?.as_bool()? {
            resp.get("result")
        } else {
            anyhow::bail!(
                "server error (id {}): {}",
                resp.get("id")?.as_i64().unwrap_or(-1),
                resp.get("error")?.as_str().unwrap_or("?")
            )
        }
    }

    /// Convenience: request a clean shutdown and return the ack payload.
    pub fn shutdown(&mut self, id: i64) -> Result<Json> {
        let resp = self.call(&Json::obj().with("id", id).with("op", "shutdown"))?;
        Self::expect_ok(&resp).map(|j| j.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_grows_exponentially_then_caps() {
        let base = Duration::from_millis(10);
        let mut prev = Duration::ZERO;
        for attempt in 0..SHED_RETRY_BUDGET {
            let d = shed_backoff(base, attempt, &[1, 2, 3]);
            // At least the un-jittered exponential floor, never past cap.
            let floor = base.saturating_mul(1 << attempt).min(SHED_BACKOFF_CAP);
            assert!(d >= floor, "attempt {attempt}: {d:?} < floor {floor:?}");
            assert!(d <= SHED_BACKOFF_CAP, "attempt {attempt}: {d:?} over cap");
            assert!(d >= prev || d == SHED_BACKOFF_CAP);
            prev = d;
        }
        // A huge attempt count stays pinned at the cap (no shift overflow).
        assert_eq!(shed_backoff(base, 40, &[7]), SHED_BACKOFF_CAP);
    }

    #[test]
    fn backoff_jitter_is_deterministic_and_bounded() {
        let base = Duration::from_millis(100);
        let a = shed_backoff(base, 0, &[10, 11]);
        let b = shed_backoff(base, 0, &[10, 11]);
        assert_eq!(a, b, "same shed set, same attempt ⇒ same sleep");
        // Different shed sets (or attempts) spread out within [0, base/2).
        let c = shed_backoff(base, 0, &[10, 12]);
        assert!(a >= base && a < base + base / 2);
        assert!(c >= base && c < base + base / 2);
        // Zero base never panics (jitter modulus is clamped to ≥ 1).
        assert_eq!(shed_backoff(Duration::ZERO, 0, &[]), Duration::ZERO);
    }

    #[test]
    fn lost_requests_are_retried_once_on_a_fresh_connection() {
        use crate::serve::codec::request_id;
        use std::net::TcpListener;

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            // conn 1: answer at most the first request, then slam shut —
            // everything unanswered goes Lost on the client
            let (mut s, _) = listener.accept().unwrap();
            let mut r = BufReader::new(s.try_clone().unwrap());
            let mut line = String::new();
            if r.read_line(&mut line).map(|n| n > 0).unwrap_or(false) {
                let resp =
                    format!("{{\"id\":{},\"ok\":true,\"result\":{{\"n\":1}}}}\n", request_id(line.trim()));
                let _ = s.write_all(resp.as_bytes());
            }
            drop(s);
            // conn 2 (the Lost redial): answer everything until EOF
            let (mut s, _) = listener.accept().unwrap();
            let mut r = BufReader::new(s.try_clone().unwrap());
            let mut line = String::new();
            while r.read_line(&mut line).map(|n| n > 0).unwrap_or(false) {
                let resp =
                    format!("{{\"id\":{},\"ok\":true,\"result\":{{\"n\":2}}}}\n", request_id(line.trim()));
                if s.write_all(resp.as_bytes()).is_err() {
                    break;
                }
                line.clear();
            }
        });

        let mut c = Client::connect(&addr).unwrap();
        let reqs = vec![
            Json::obj().with("id", 1i64).with("op", "status"),
            Json::obj().with("id", 2i64).with("op", "status"),
        ];
        let out = c.call_many_retry_shed(&reqs, Duration::from_millis(1));
        // id 2 was lost when conn 1 died; the one-shot Lost retry redials
        // and recovers it within the same call.
        match &out[1] {
            Outcome::Ok(j) => {
                assert_eq!(j.get("n").unwrap().as_i64().unwrap(), 2, "answered by the redial")
            }
            other => panic!("lost request was not recovered: {other:?}"),
        }
        assert!(
            !matches!(out[0], Outcome::Lost),
            "id 1 must be answered on conn 1 or recovered by the redial: {:?}",
            out[0]
        );
        server.join().unwrap();
    }
}
