//! `fames serve` — a concurrent batched evaluation daemon (the repo's
//! first request-driven workload).
//!
//! Dependency-free serving stack: a std [`TcpListener`] accepts newline-
//! delimited JSON connections ([`codec`]), a [`registry::Registry`] holds N
//! warmed model sessions with per-model routing, and a [`batcher::Batcher`]
//! coalesces concurrent requests into `util::par` waves — the worker pool
//! drives the same fused kernel paths (shared `kernel::Scratch` arenas,
//! `OnceLock` coefficient caches) a direct `Session` call would.
//!
//! # Request lifecycle
//!
//! ```text
//! client ──line──▶ reader thread ──Job──▶ Batcher FIFO
//!                   (parse, route            │ drain ≤ max_batch
//!                    status/shutdown         ▼
//!                    answered inline)   dispatcher thread
//!                                       par_map wave (util::par)
//!                                       ┌─────────┬─────────┐
//!                                       evaluate  energy  select
//!                                       (Session) (EnergyModel) (MCKP)
//!                                            │
//! client ◀──line── writer thread ◀──mpsc─────┘  (id-tagged responses)
//! ```
//!
//! # Bit-identity guarantee
//!
//! Batching changes *when* a request runs, never *what* it computes: each
//! wave entry is handled by exactly the call an embedder would make on the
//! warmed `Session` (`evaluate` / `evaluate_with`), on `EnergyModel`, or on
//! `select::solve_exact` — all of which are bit-deterministic at every
//! worker count (`tests/par_equivalence.rs`). Responses therefore compare
//! byte-for-byte against direct-call references at `--jobs` 1/4/auto
//! (`tests/serve_smoke.rs` pins this over the wire).
//!
//! Shutdown is graceful: `{"op":"shutdown"}` is acked immediately, the
//! listener stops accepting, the batcher drains every queued request, and
//! [`Server::run`] returns.

pub mod batcher;
pub mod client;
pub mod codec;
pub mod registry;

pub use client::Client;
pub use codec::{Op, Request, PROTOCOL};
pub use registry::{ModelEntry, Registry};

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use anyhow::{Context, Result};

use batcher::{Batcher, Job};

/// Most eval batches one `evaluate` request may ask for. Waves run to
/// completion before the next one starts, so an unbounded request would
/// head-of-line-block every other client for its whole duration — a
/// one-line unauthenticated DoS without this cap.
pub const MAX_EVAL_BATCHES: usize = 1024;

use crate::energy::EnergyModel;
use crate::json::Json;
use crate::pipeline::FamesConfig;
use crate::runtime::Runtime;
use crate::select::{self, Choice};
use crate::util::par;

/// Serving configuration (CLI `fames serve`).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address; port 0 asks the OS for a free port (tests/bench).
    pub addr: String,
    /// `<model>/<cfg>` specs to warm and route to.
    pub models: Vec<String>,
    /// Most requests one dispatcher wave may carry.
    pub max_batch: usize,
    /// Artifact root, seed, jobs, training and cache knobs shared by every
    /// model entry.
    pub base: FamesConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        let base = FamesConfig::default();
        ServeConfig {
            addr: "127.0.0.1:4271".to_string(),
            models: vec![format!("{}/{}", base.model, base.cfg)],
            max_batch: 16,
            base,
        }
    }
}

/// Per-op request counters (status + bench assertions).
#[derive(Default)]
pub struct Stats {
    pub evaluate: AtomicU64,
    pub energy: AtomicU64,
    pub select: AtomicU64,
    pub errors: AtomicU64,
}

impl Stats {
    fn count(&self, op: &Op) {
        match op {
            Op::Evaluate { .. } => self.evaluate.fetch_add(1, Ordering::Relaxed),
            Op::Energy { .. } => self.energy.fetch_add(1, Ordering::Relaxed),
            Op::Select { .. } => self.select.fetch_add(1, Ordering::Relaxed),
            Op::Status | Op::Shutdown => 0,
        };
    }

    pub fn total(&self) -> u64 {
        self.evaluate.load(Ordering::Relaxed)
            + self.energy.load(Ordering::Relaxed)
            + self.select.load(Ordering::Relaxed)
    }
}

/// State shared by the accept loop, connection threads and the dispatcher.
struct Shared {
    registry: Registry,
    rt: Arc<Runtime>,
    batcher: Batcher,
    stats: Stats,
    stop: AtomicBool,
    addr: SocketAddr,
    started: Instant,
    jobs: usize,
}

impl Shared {
    fn status_json(&self) -> Json {
        let exec = self.rt.total_stats();
        let mut models = Json::arr();
        for e in self.registry.entries() {
            models.push(
                Json::obj()
                    .with("key", e.key.as_str())
                    .with("layers", e.session.art.manifest.layers.len())
                    .with("warm_secs", e.warm_secs)
                    .with(
                        "library",
                        match e.lib_hit {
                            Some(true) => "hit",
                            Some(false) => "miss",
                            None => "off",
                        },
                    ),
            );
        }
        Json::obj()
            .with("protocol", PROTOCOL)
            .with("backend", self.rt.platform())
            .with("models", models)
            .with("uptime_secs", self.started.elapsed().as_secs_f64())
            .with("pending", self.batcher.pending())
            .with("max_batch", self.batcher.max_batch)
            .with("jobs", par::effective_jobs(self.jobs))
            .with(
                "requests",
                Json::obj()
                    .with("evaluate", self.stats.evaluate.load(Ordering::Relaxed) as usize)
                    .with("energy", self.stats.energy.load(Ordering::Relaxed) as usize)
                    .with("select", self.stats.select.load(Ordering::Relaxed) as usize)
                    .with("errors", self.stats.errors.load(Ordering::Relaxed) as usize)
                    .with("total", self.stats.total() as usize),
            )
            .with(
                "exec",
                Json::obj()
                    .with("calls", exec.calls as usize)
                    .with("total_secs", exec.total_secs),
            )
    }

    fn begin_shutdown(&self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return; // already shutting down
        }
        self.batcher.close();
        // the accept loop blocks in `accept`; poke it awake so it can see
        // the stop flag and exit
        let _ = TcpStream::connect(self.addr);
    }
}

/// A bound, warmed serve daemon. `bind` does all the expensive work
/// (session warm-up, library characterization); `run` is the accept loop.
pub struct Server {
    listener: TcpListener,
    shared: Arc<Shared>,
}

impl Server {
    /// Warm every configured model and bind the listener.
    pub fn bind(cfg: &ServeConfig) -> Result<Server> {
        let rt = Arc::new(Runtime::from_env()?);
        let registry = Registry::open(rt.clone(), &cfg.base, &cfg.models)?;
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding fames serve to {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        Ok(Server {
            listener,
            shared: Arc::new(Shared {
                registry,
                rt,
                batcher: Batcher::new(cfg.max_batch),
                stats: Stats::default(),
                stop: AtomicBool::new(false),
                addr,
                started: Instant::now(),
                jobs: cfg.base.jobs,
            }),
        })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// The warmed model registry (CLI startup table, tests).
    pub fn registry(&self) -> &Registry {
        &self.shared.registry
    }

    /// Serve until a `shutdown` request: accept connections, batch compute
    /// requests, answer inline ops. Returns only after the queue has
    /// drained **and** every connection's writer has flushed its final
    /// responses, so a caller may exit the process immediately.
    pub fn run(self) -> Result<()> {
        let shared = self.shared;
        let dispatcher = {
            let shared = shared.clone();
            std::thread::spawn(move || dispatch_loop(&shared))
        };
        // (reader thread handle, read-half clone used to unblock it)
        let mut conns: Vec<(std::thread::JoinHandle<()>, TcpStream)> = Vec::new();
        for stream in self.listener.incoming() {
            if shared.stop.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            // reap finished connections so a long-lived daemon does not
            // accumulate one JoinHandle per connection ever accepted
            conns.retain(|(h, _)| !h.is_finished());
            let clone = stream.try_clone();
            let shared = shared.clone();
            let handle = std::thread::spawn(move || serve_connection(stream, &shared));
            match clone {
                Ok(c) => conns.push((handle, c)),
                Err(_) => drop(handle), // can't unblock it later; detach
            }
        }
        // `begin_shutdown` already closed the batcher; wait for the queue
        // to drain so every accepted request is answered
        dispatcher.join().expect("serve: dispatcher panicked");
        // unblock readers stuck in read_line (a client holding its
        // connection open must not wedge shutdown): closing the read half
        // EOFs the reader, which drops its sender; the writer then drains
        // and flushes every remaining queued response before exiting
        for (_, stream) in &conns {
            let _ = stream.shutdown(std::net::Shutdown::Read);
        }
        for (handle, _) in conns {
            let _ = handle.join();
        }
        Ok(())
    }
}

/// Dispatcher: drain request waves and score each wave as one parallel
/// `util::par` map — the "batch concurrent requests into fused kernel
/// invocations" half of the serving layer.
fn dispatch_loop(shared: &Shared) {
    while let Some(wave) = shared.batcher.next_wave() {
        let mut requests = Vec::with_capacity(wave.len());
        let mut replies = Vec::with_capacity(wave.len());
        for job in wave {
            requests.push(job.request);
            replies.push(job.reply);
        }
        let lines = par::par_map(&requests, shared.jobs, |_, req| {
            let resp = match handle_compute(shared, req) {
                Ok(result) => codec::ok_response(req.id, result),
                Err(e) => {
                    shared.stats.errors.fetch_add(1, Ordering::Relaxed);
                    codec::err_response(req.id, &format!("{e:#}"))
                }
            };
            resp.compact()
        });
        for (reply, line) in replies.iter().zip(lines) {
            let _ = reply.send(line); // a vanished client is not an error
        }
    }
}

/// Score one compute request against its routed model entry. Every arm is
/// exactly the call an embedder would make directly — the bit-identity
/// contract of the serving layer.
fn handle_compute(shared: &Shared, req: &Request) -> Result<Json> {
    let entry = shared.registry.get(req.model.as_deref())?;
    match &req.op {
        Op::Evaluate { batches, selection } => {
            anyhow::ensure!(
                (1..=MAX_EVAL_BATCHES).contains(batches),
                "batches must be in 1..={MAX_EVAL_BATCHES} (got {batches})"
            );
            let r = match selection {
                None => entry.session.evaluate(*batches)?,
                Some(picks) => {
                    let e_list = entry.selection_tensors(picks)?;
                    entry.session.evaluate_with(&e_list, *batches)?
                }
            };
            Ok(codec::eval_json(&r))
        }
        Op::Energy { selection } => {
            let sel = entry.resolve_selection(selection)?;
            let em = EnergyModel::new(&entry.session.art.manifest, &entry.library);
            let names: Vec<String> = sel.iter().map(|am| am.name.clone()).collect();
            Ok(Json::obj()
                .with("energy", em.model_energy(&sel))
                .with("ratio_vs_exact", em.ratio_vs_exact(&sel)?)
                .with("ratio_vs_8bit", em.ratio_vs_8bit(&sel)?)
                .with("names", names))
        }
        Op::Select { r_energy, omega } => {
            let manifest = &entry.session.art.manifest;
            anyhow::ensure!(
                omega.len() == manifest.layers.len(),
                "omega has {} rows, model '{}' has {} layers",
                omega.len(),
                entry.key,
                manifest.layers.len()
            );
            let em = EnergyModel::new(manifest, &entry.library);
            let mut problem: Vec<Vec<Choice>> = Vec::with_capacity(manifest.layers.len());
            let mut names: Vec<Vec<String>> = Vec::with_capacity(manifest.layers.len());
            for (k, layer) in manifest.layers.iter().enumerate() {
                let muls = entry.library.for_bits(layer.a_bits, layer.w_bits);
                anyhow::ensure!(
                    omega[k].len() == muls.len(),
                    "omega row {k} has {} entries, library has {} candidates",
                    omega[k].len(),
                    muls.len()
                );
                problem.push(
                    muls.iter()
                        .zip(&omega[k])
                        .map(|(am, &v)| Choice { cost: em.layer_energy(layer, am), value: v })
                        .collect(),
                );
                names.push(muls.iter().map(|m| m.name.clone()).collect());
            }
            let budget = r_energy * em.model_energy_exact()?;
            let sol = select::solve_exact(&problem, budget)?;
            let picked: Vec<String> = sol
                .picks
                .iter()
                .enumerate()
                .map(|(k, &i)| names[k][i].clone())
                .collect();
            Ok(codec::solution_json(&sol, &picked))
        }
        Op::Status | Op::Shutdown => unreachable!("inline ops never reach the batcher"),
    }
}

/// Per-connection reader: parse lines, answer `status`/`shutdown` inline,
/// enqueue compute ops. A paired writer thread owns the outbound half so
/// batcher waves and inline answers can interleave safely.
fn serve_connection(stream: TcpStream, shared: &Shared) {
    use std::io::{BufRead, BufReader, BufWriter, Write};

    let Ok(write_half) = stream.try_clone() else { return };
    let (tx, rx) = mpsc::channel::<String>();
    let writer = std::thread::spawn(move || {
        let mut w = BufWriter::new(write_half);
        for line in rx {
            if w.write_all(line.as_bytes())
                .and_then(|_| w.write_all(b"\n"))
                .and_then(|_| w.flush())
                .is_err()
            {
                break;
            }
        }
    });

    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => break, // EOF / reset
            Ok(_) => {}
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        match codec::parse_request(trimmed) {
            Err(e) => {
                shared.stats.errors.fetch_add(1, Ordering::Relaxed);
                let id = codec::request_id(trimmed);
                let _ = tx.send(codec::err_response(id, &format!("{e:#}")).compact());
            }
            Ok(req) => match req.op {
                Op::Status => {
                    let _ = tx.send(codec::ok_response(req.id, shared.status_json()).compact());
                }
                Op::Shutdown => {
                    let _ = tx.send(
                        codec::ok_response(req.id, Json::obj().with("stopping", true)).compact(),
                    );
                    shared.begin_shutdown();
                }
                _ => {
                    shared.stats.count(&req.op);
                    let id = req.id;
                    if !shared.batcher.enqueue(Job { request: req, reply: tx.clone() }) {
                        shared.stats.errors.fetch_add(1, Ordering::Relaxed);
                        let err = codec::err_response(id, "server is shutting down");
                        let _ = tx.send(err.compact());
                    }
                }
            },
        }
    }
    drop(tx);
    let _ = writer.join();
}
