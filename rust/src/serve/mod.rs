//! `fames serve` — a concurrent batched evaluation daemon (the repo's
//! first request-driven workload).
//!
//! Dependency-free serving stack with two front doors over one engine:
//! a std [`TcpListener`] accepts newline-delimited JSON connections
//! (decoded by the zero-alloc [`wire`] path; [`codec`] remains the tree-
//! based reference implementation), an optional HTTP/1.1 gateway
//! ([`http`]) maps typed routes onto the same decoder, a
//! [`registry::Registry`] holds N warmed model sessions with per-model
//! routing, and a [`batcher::Batcher`] coalesces concurrent requests into
//! `util::par` waves — the worker pool drives the same fused kernel paths
//! (shared `kernel::Scratch` arenas, `OnceLock` coefficient caches) a
//! direct `Session` call would. [`admission`] keeps all of it bounded:
//! connection cap, bounded queue with explicit load-shed responses, and
//! slow-client eviction.
//!
//! # Request lifecycle
//!
//! ```text
//!                      admission::Gate (max_conns; over cap → shed, close)
//!                           │
//! NDJSON client ──line──▶ reader thread ── wire::decode_line ──Job──▶
//! HTTP client ──POST /v1/*─▶ http thread ── wire::decode_body ──Job──▶
//!                                                  │
//!                              Batcher: per-client queues (≤ max_pending,
//!                                       over → "shed":true / HTTP 503)
//!                                                  │ round-robin wave
//!                                                  ▼
//!                                         dispatcher thread
//!                                         par_map wave (util::par)
//!                                         ┌─────────┬─────────┐
//!                                         evaluate  energy  select
//!                                         (Session) (EnergyModel) (MCKP)
//!                                                  │
//! client ◀── writer thread ◀── bounded sink ◀──────┘ (full/timeout →
//!                                                     evict connection)
//! ```
//!
//! # Bit-identity guarantee
//!
//! Batching changes *when* a request runs, never *what* it computes: each
//! wave entry is handled by exactly the call an embedder would make on the
//! warmed `Session` (`evaluate` / `evaluate_with`), on `EnergyModel`, or on
//! `select::solve_exact` — all of which are bit-deterministic at every
//! worker count (`tests/par_equivalence.rs`). Responses stream out through
//! [`wire`]'s encoder, byte-identical to the tree codec's output, and
//! therefore compare byte-for-byte against direct-call references at
//! `--jobs` 1/4/auto (`tests/serve_smoke.rs` pins this over the wire).
//!
//! Shutdown is graceful: `{"op":"shutdown"}` is acked immediately, both
//! listeners stop accepting, the batcher drains every queued request, and
//! [`Server::run`] returns.

pub mod admission;
pub mod batcher;
pub mod client;
pub mod codec;
pub mod fault;
pub mod health;
pub mod http;
pub mod registry;
pub mod ring;
pub mod router;
pub mod wire;

pub use client::{Client, Outcome};
pub use codec::{Op, Request, PROTOCOL};
pub use fault::FaultPlan;
pub use health::{Liveness, Membership};
pub use registry::{ModelEntry, Registry};
pub use ring::Ring;
pub use router::{Router, RouterConfig};

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use batcher::{Batcher, Job};

/// Most eval batches one `evaluate` request may ask for. Waves run to
/// completion before the next one starts, so an unbounded request would
/// head-of-line-block every other client for its whole duration — a
/// one-line unauthenticated DoS without this cap.
pub const MAX_EVAL_BATCHES: usize = 1024;

/// Responses that may queue for one NDJSON connection whose client is not
/// reading them. Past this, the dispatcher evicts the connection rather
/// than blocking a wave (see [`ReplySink::deliver`]).
const REPLY_BUFFER: usize = 256;

use std::collections::BTreeMap;

use crate::energy::EnergyModel;
use crate::json::Json;
use crate::pipeline::{self, ActiveSelection, EvalResult, FamesConfig, StageRun};
use crate::runtime::Runtime;
use crate::select::{self, Choice};
use crate::util::par;

/// Serving configuration (CLI `fames serve`).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// NDJSON bind address; port 0 asks the OS for a free port
    /// (tests/bench).
    pub addr: String,
    /// Optional HTTP/1.1 gateway bind address (CLI `http=`); `None`
    /// serves NDJSON only.
    pub http_addr: Option<String>,
    /// `<model>/<cfg>` specs to warm and route to.
    pub models: Vec<String>,
    /// Most requests one dispatcher wave may carry.
    pub max_batch: usize,
    /// Admission: most simultaneously served connections (NDJSON + HTTP
    /// combined); over the cap, connections get one shed response and
    /// close.
    pub max_conns: usize,
    /// Admission: most queued-but-undispatched compute requests; over it,
    /// new requests are shed with an explicit retry hint.
    pub max_pending: usize,
    /// Most bytes one NDJSON request line (or HTTP body) may carry.
    pub max_line: usize,
    /// Per-flush write timeout (ms); a client that cannot drain its
    /// responses within it is evicted instead of stalling its writer.
    pub write_timeout_ms: u64,
    /// Structured per-request access log (HTTP gateway) on stderr.
    pub access_log: bool,
    /// Deterministic fault-injection schedule (chaos tests/benches attach
    /// one directly; the CLI reads `FAMES_FAULT`). `None` = no injection.
    pub fault: Option<Arc<fault::FaultPlan>>,
    /// Artifact root, seed, jobs, training and cache knobs shared by every
    /// model entry.
    pub base: FamesConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        let base = FamesConfig::default();
        ServeConfig {
            addr: "127.0.0.1:4271".to_string(),
            http_addr: None,
            models: vec![format!("{}/{}", base.model, base.cfg)],
            max_batch: 16,
            max_conns: 1024,
            max_pending: 4096,
            max_line: 1 << 20,
            write_timeout_ms: 10_000,
            access_log: false,
            fault: None,
            base,
        }
    }
}

/// Per-op request counters (status + bench assertions).
#[derive(Default)]
pub struct Stats {
    pub evaluate: AtomicU64,
    pub energy: AtomicU64,
    pub select: AtomicU64,
    pub errors: AtomicU64,
    /// Requests refused by the bounded queue (explicit shed responses).
    pub shed: AtomicU64,
    /// Connections evicted for not draining their responses.
    pub evicted: AtomicU64,
    /// Lines refused for exceeding `max_line`.
    pub oversized: AtomicU64,
    /// Requests served through the HTTP gateway (also counted per-op).
    pub http: AtomicU64,
    /// Artifact replication ops (`artifact_get` + `artifact_put`).
    pub artifact: AtomicU64,
    /// Live operating-point changes (`reconfigure`).
    pub reconfigure: AtomicU64,
}

impl Stats {
    fn count(&self, op: &Op) {
        match op {
            Op::Evaluate { .. } => self.evaluate.fetch_add(1, Ordering::Relaxed),
            Op::Energy { .. } => self.energy.fetch_add(1, Ordering::Relaxed),
            Op::Select { .. } => self.select.fetch_add(1, Ordering::Relaxed),
            Op::Reconfigure { .. } => self.reconfigure.fetch_add(1, Ordering::Relaxed),
            Op::ArtifactGet { .. } | Op::ArtifactPut { .. } => {
                self.artifact.fetch_add(1, Ordering::Relaxed)
            }
            Op::Health | Op::Status | Op::Shutdown => 0,
        };
    }

    pub fn total(&self) -> u64 {
        self.evaluate.load(Ordering::Relaxed)
            + self.energy.load(Ordering::Relaxed)
            + self.select.load(Ordering::Relaxed)
    }
}

/// Typed dispatcher output: `evaluate` streams through the zero-tree
/// encoder (with the optional active-selection fingerprint tag); the
/// colder ops carry their (small) payload tree.
pub enum ComputeOut {
    Eval(EvalResult, Option<String>),
    Other(Json),
}

/// Per-job dispatcher verdict: the op's output or the error-envelope
/// message.
pub type WaveResult = std::result::Result<ComputeOut, String>;

/// Write-half handle used to evict a connection from outside its own
/// threads (dispatcher on sink overflow, writer on flush timeout).
pub struct ConnHandle {
    stream: TcpStream,
}

impl ConnHandle {
    fn new(stream: TcpStream) -> ConnHandle {
        ConnHandle { stream }
    }

    /// Tear the connection down; both halves unblock with errors/EOF.
    fn evict(&self) {
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
    }
}

/// Where a dispatched job's response goes back out.
pub enum ReplySink {
    /// NDJSON connection: a pre-encoded response line into the writer
    /// thread's bounded channel. `conn` (when available) lets the
    /// dispatcher evict a stalled client instead of blocking the wave.
    Line {
        tx: mpsc::SyncSender<String>,
        conn: Option<Arc<ConnHandle>>,
    },
    /// HTTP request thread, rendezvous-waiting for exactly one result.
    Http(mpsc::SyncSender<WaveResult>),
}

impl ReplySink {
    /// Deliver one job's outcome. Never blocks the dispatcher: a full
    /// NDJSON sink means the client has [`REPLY_BUFFER`] unread responses
    /// queued and gets evicted; an HTTP sink is a rendezvous with a
    /// waiting thread.
    fn deliver(self, id: i64, out: WaveResult, stats: &Stats) {
        match self {
            ReplySink::Line { tx, conn } => {
                let line = match &out {
                    Ok(ComputeOut::Eval(r, sel)) => wire::eval_ok_line(id, r, sel.as_deref()),
                    Ok(ComputeOut::Other(j)) => wire::ok_line(id, j),
                    Err(msg) => wire::err_line(id, msg),
                };
                match tx.try_send(line) {
                    Ok(()) => {}
                    Err(mpsc::TrySendError::Full(_)) => {
                        stats.evicted.fetch_add(1, Ordering::Relaxed);
                        if let Some(c) = conn {
                            c.evict();
                        }
                    }
                    Err(mpsc::TrySendError::Disconnected(_)) => {} // client gone
                }
            }
            ReplySink::Http(tx) => {
                let _ = tx.send(out); // capacity 1, receiver is waiting
            }
        }
    }
}

/// State shared by the accept loops, connection threads and the
/// dispatcher. (Child modules — `http` — reach it as `super::Shared`.)
struct Shared {
    registry: Registry,
    rt: Arc<Runtime>,
    /// Local artifact-store tier answering `artifact_get`/`artifact_put`
    /// (peers replicate through it); `None` when caching is disabled.
    store: Option<crate::store::Store>,
    batcher: Batcher,
    stats: Stats,
    stop: AtomicBool,
    addr: SocketAddr,
    http_addr: Option<SocketAddr>,
    started: Instant,
    jobs: usize,
    gate: Arc<admission::Gate>,
    max_line: usize,
    write_timeout_ms: u64,
    access_log: bool,
    /// Monotonic connection ids — the batcher's fairness keys.
    clients: AtomicU64,
    /// Process generation reported by `health` — changes across restarts,
    /// so the router's prober can tell "recovered" from "replaced".
    generation: u64,
    /// Recent dispatch-wave latencies — the `health` p99 source.
    waves: health::WaveWindow,
    /// Injected failure schedule (tests/chaos only; `None` in production
    /// unless the operator set `FAMES_FAULT`).
    fault: Option<Arc<fault::FaultPlan>>,
}

impl Shared {
    fn status_json(&self) -> Json {
        let exec = self.rt.total_stats();
        let mut models = Json::arr();
        for e in self.registry.entries() {
            models.push(
                Json::obj()
                    .with("key", e.key.as_str())
                    .with("layers", e.session.art.manifest.layers.len())
                    .with("warm_secs", e.warm_secs)
                    .with(
                        "library",
                        match e.lib_hit {
                            Some(true) => "hit",
                            Some(false) => "miss",
                            None => "off",
                        },
                    )
                    .with(
                        "params",
                        match e.params_source {
                            crate::pipeline::ParamsSource::StateFile => "state_file",
                            crate::pipeline::ParamsSource::Store => "store",
                            crate::pipeline::ParamsSource::Trained => "trained",
                        },
                    )
                    .with(
                        "active_selection",
                        match e.active_fingerprint() {
                            Some(fp) => Json::Str(fp.hex()),
                            None => Json::Null,
                        },
                    )
                    .with(
                        "pareto",
                        Json::obj()
                            .with("points", e.pareto.as_ref().map_or(0, |f| f.points.len()))
                            .with("hits", e.pareto_hits.load(Ordering::Relaxed) as usize)
                            .with("misses", e.pareto_misses.load(Ordering::Relaxed) as usize)
                            .with("swaps", e.swaps.load(Ordering::Relaxed) as usize),
                    ),
            );
        }
        Json::obj()
            .with("protocol", PROTOCOL)
            .with("backend", self.rt.platform())
            .with("generation", self.generation as f64)
            .with("models", models)
            .with("uptime_secs", self.started.elapsed().as_secs_f64())
            .with("pending", self.batcher.pending())
            .with("max_batch", self.batcher.max_batch)
            .with("jobs", par::effective_jobs(self.jobs))
            .with(
                "requests",
                Json::obj()
                    .with("evaluate", self.stats.evaluate.load(Ordering::Relaxed) as usize)
                    .with("energy", self.stats.energy.load(Ordering::Relaxed) as usize)
                    .with("select", self.stats.select.load(Ordering::Relaxed) as usize)
                    .with("reconfigure", self.stats.reconfigure.load(Ordering::Relaxed) as usize)
                    .with("errors", self.stats.errors.load(Ordering::Relaxed) as usize)
                    .with("http", self.stats.http.load(Ordering::Relaxed) as usize)
                    .with("artifact", self.stats.artifact.load(Ordering::Relaxed) as usize)
                    .with("total", self.stats.total() as usize),
            )
            .with(
                "admission",
                Json::obj()
                    .with("active_conns", self.gate.active())
                    .with("max_conns", self.gate.max_conns())
                    .with("max_pending", self.batcher.max_pending)
                    .with("shed_conns", self.gate.shed_total() as usize)
                    .with("shed_requests", self.stats.shed.load(Ordering::Relaxed) as usize)
                    .with("evicted", self.stats.evicted.load(Ordering::Relaxed) as usize)
                    .with("oversized", self.stats.oversized.load(Ordering::Relaxed) as usize),
            )
            .with(
                "exec",
                Json::obj()
                    .with("calls", exec.calls as usize)
                    .with("total_secs", exec.total_secs),
            )
    }

    fn begin_shutdown(&self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return; // already shutting down
        }
        self.batcher.close();
        // the accept loops block in `accept`; poke them awake so they can
        // see the stop flag and exit
        let _ = TcpStream::connect(self.addr);
        if let Some(addr) = self.http_addr {
            let _ = TcpStream::connect(addr);
        }
    }
}

/// A bound, warmed serve daemon. `bind` does all the expensive work
/// (session warm-up, library characterization); `run` is the accept loop.
pub struct Server {
    listener: TcpListener,
    http_listener: Option<TcpListener>,
    shared: Arc<Shared>,
}

impl Server {
    /// Warm every configured model and bind the listener(s).
    pub fn bind(cfg: &ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding fames serve to {}", cfg.addr))?;
        let http_listener = match &cfg.http_addr {
            Some(a) => Some(
                TcpListener::bind(a)
                    .with_context(|| format!("binding fames serve http to {a}"))?,
            ),
            None => None,
        };
        Server::bind_on(cfg, listener, http_listener)
    }

    /// Warm every configured model behind **pre-bound** listeners. Fleet
    /// orchestration (bench, tests) binds all shard ports first — so every
    /// peer address is known before any shard starts warming — then hands
    /// each listener over here; no shard races another's port assignment.
    pub fn bind_on(
        cfg: &ServeConfig,
        listener: TcpListener,
        http_listener: Option<TcpListener>,
    ) -> Result<Server> {
        let rt = Arc::new(Runtime::from_env()?);
        let registry = Registry::open(rt.clone(), &cfg.base, &cfg.models)?;
        let addr = listener.local_addr()?;
        let http_addr = match &http_listener {
            Some(l) => Some(l.local_addr()?),
            None => None,
        };
        Ok(Server {
            listener,
            http_listener,
            shared: Arc::new(Shared {
                registry,
                store: cfg.base.store(),
                rt,
                batcher: Batcher::new(cfg.max_batch, cfg.max_pending),
                stats: Stats::default(),
                stop: AtomicBool::new(false),
                addr,
                http_addr,
                started: Instant::now(),
                jobs: cfg.base.jobs,
                gate: Arc::new(admission::Gate::new(cfg.max_conns)),
                max_line: cfg.max_line.max(64),
                write_timeout_ms: cfg.write_timeout_ms.max(1),
                access_log: cfg.access_log,
                clients: AtomicU64::new(0),
                generation: std::time::SystemTime::now()
                    .duration_since(std::time::UNIX_EPOCH)
                    .map(|d| d.as_millis() as u64)
                    .unwrap_or(0),
                waves: health::WaveWindow::new(256),
                fault: cfg.fault.clone(),
            }),
        })
    }

    /// The bound NDJSON address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// The bound HTTP gateway address, when one is configured.
    pub fn http_local_addr(&self) -> Option<SocketAddr> {
        self.shared.http_addr
    }

    /// The warmed model registry (CLI startup table, tests).
    pub fn registry(&self) -> &Registry {
        &self.shared.registry
    }

    /// Serve until a `shutdown` request: accept connections (through the
    /// admission gate), batch compute requests, answer inline ops. Returns
    /// only after the queue has drained **and** every connection's writer
    /// has flushed its final responses, so a caller may exit the process
    /// immediately.
    pub fn run(self) -> Result<()> {
        let shared = self.shared;
        let dispatcher = {
            let shared = shared.clone();
            std::thread::spawn(move || dispatch_loop(&shared))
        };
        // the HTTP gateway runs its own accept loop and joins its
        // connection threads before returning
        let http_accept = self.http_listener.map(|l| {
            let shared = shared.clone();
            std::thread::spawn(move || http::accept_loop(l, &shared))
        });
        // (reader thread handle, read-half clone used to unblock it)
        let mut conns: Vec<(std::thread::JoinHandle<()>, TcpStream)> = Vec::new();
        for stream in self.listener.incoming() {
            if shared.stop.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            // injected refuse-accept: close without a byte, so the peer
            // sees connect-then-EOF (the crashed-shard signature)
            if let Some(f) = &shared.fault {
                if f.refuse_conn() {
                    drop(stream);
                    continue;
                }
            }
            // reap finished connections so a long-lived daemon does not
            // accumulate one JoinHandle per connection ever accepted
            conns.retain(|(h, _)| !h.is_finished());
            let Some(guard) = shared.gate.try_enter() else {
                refuse_connection(stream);
                continue;
            };
            let client_id = shared.clients.fetch_add(1, Ordering::Relaxed);
            let clone = stream.try_clone();
            let shared2 = shared.clone();
            let handle = std::thread::spawn(move || {
                serve_connection(stream, &shared2, client_id, guard)
            });
            match clone {
                Ok(c) => conns.push((handle, c)),
                Err(_) => drop(handle), // can't unblock it later; detach
            }
        }
        // `begin_shutdown` already closed the batcher; wait for the queue
        // to drain so every accepted request is answered
        dispatcher.join().expect("serve: dispatcher panicked");
        // unblock readers stuck in their line read (a client holding its
        // connection open must not wedge shutdown): closing the read half
        // EOFs the reader, which drops its sender; the writer then drains
        // and flushes every remaining queued response before exiting
        for (_, stream) in &conns {
            let _ = stream.shutdown(std::net::Shutdown::Read);
        }
        for (handle, _) in conns {
            let _ = handle.join();
        }
        if let Some(h) = http_accept {
            let _ = h.join();
        }
        Ok(())
    }
}

/// Answer a gate-refused NDJSON connection with one shed line and close.
/// Runs on a throwaway thread so a client that never reads cannot stall
/// the accept loop.
fn refuse_connection(stream: TcpStream) {
    std::thread::spawn(move || {
        use std::io::Write;
        let mut s = stream;
        let _ = s.set_write_timeout(Some(Duration::from_millis(1000)));
        let mut line = wire::shed_line(-1, admission::OVERLOADED_CONNS);
        line.push('\n');
        let _ = s.write_all(line.as_bytes());
    });
}

/// Dispatcher: drain request waves and score each wave as one parallel
/// `util::par` map — the "batch concurrent requests into fused kernel
/// invocations" half of the serving layer.
fn dispatch_loop(shared: &Shared) {
    while let Some(wave) = shared.batcher.next_wave() {
        let t0 = Instant::now();
        let mut requests = Vec::with_capacity(wave.len());
        let mut sinks = Vec::with_capacity(wave.len());
        for job in wave {
            requests.push(job.request);
            sinks.push(job.sink);
        }
        // one operating-point snapshot per wave: a concurrent reconfigure
        // takes effect at the *next* wave boundary, so every request in
        // this wave is answered (and tagged) under exactly one selection
        let actives = shared.registry.active_snapshot();
        let outs: Vec<WaveResult> = par::par_map(&requests, shared.jobs, |_, req| {
            handle_compute(shared, &actives, req).map_err(|e| format!("{e:#}"))
        });
        for ((req, sink), out) in requests.iter().zip(sinks).zip(outs) {
            if out.is_err() {
                shared.stats.errors.fetch_add(1, Ordering::Relaxed);
            }
            sink.deliver(req.id, out, &shared.stats);
        }
        // wave latency feeds the `health` p99 the router probes on
        shared.waves.record(t0.elapsed().as_secs_f64() * 1e3);
    }
}

/// Score one compute request against its routed model entry. Every arm is
/// exactly the call an embedder would make directly — the bit-identity
/// contract of the serving layer. `actives` is the dispatcher's per-wave
/// operating-point snapshot: a selection-less `evaluate` runs under it
/// (and is tagged with its fingerprint) when one is live.
fn handle_compute(
    shared: &Shared,
    actives: &BTreeMap<String, Arc<ActiveSelection>>,
    req: &Request,
) -> Result<ComputeOut> {
    let entry = shared.registry.get(req.model.as_deref())?;
    match &req.op {
        Op::Evaluate { batches, selection } => {
            anyhow::ensure!(
                (1..=MAX_EVAL_BATCHES).contains(batches),
                "batches must be in 1..={MAX_EVAL_BATCHES} (got {batches})"
            );
            match selection {
                None => match actives.get(&entry.key) {
                    Some(act) => {
                        let r = entry.session.evaluate_operating_point(
                            &act.e_list,
                            &act.act_q,
                            &act.lwc,
                            *batches,
                        )?;
                        Ok(ComputeOut::Eval(r, Some(act.fingerprint.hex())))
                    }
                    None => Ok(ComputeOut::Eval(entry.session.evaluate(*batches)?, None)),
                },
                Some(picks) => {
                    let e_list = entry.selection_tensors(picks)?;
                    Ok(ComputeOut::Eval(entry.session.evaluate_with(&e_list, *batches)?, None))
                }
            }
        }
        Op::Energy { selection } => {
            let sel = entry.resolve_selection(selection)?;
            let em = EnergyModel::new(&entry.session.art.manifest, &entry.library);
            let names: Vec<String> = sel.iter().map(|am| am.name.clone()).collect();
            Ok(ComputeOut::Other(
                Json::obj()
                    .with("energy", em.model_energy(&sel))
                    .with("ratio_vs_exact", em.ratio_vs_exact(&sel)?)
                    .with("ratio_vs_8bit", em.ratio_vs_8bit(&sel)?)
                    .with("names", names),
            ))
        }
        Op::Select { r_energy, omega } => {
            let manifest = &entry.session.art.manifest;
            anyhow::ensure!(
                omega.len() == manifest.layers.len(),
                "omega has {} rows, model '{}' has {} layers",
                omega.len(),
                entry.key,
                manifest.layers.len()
            );
            let em = EnergyModel::new(manifest, &entry.library);
            let mut problem: Vec<Vec<Choice>> = Vec::with_capacity(manifest.layers.len());
            let mut names: Vec<Vec<String>> = Vec::with_capacity(manifest.layers.len());
            for (k, layer) in manifest.layers.iter().enumerate() {
                let muls = entry.library.for_bits(layer.a_bits, layer.w_bits);
                anyhow::ensure!(
                    omega[k].len() == muls.len(),
                    "omega row {k} has {} entries, library has {} candidates",
                    omega[k].len(),
                    muls.len()
                );
                problem.push(
                    muls.iter()
                        .zip(&omega[k])
                        .map(|(am, &v)| Choice { cost: em.layer_energy(layer, am), value: v })
                        .collect(),
                );
                names.push(muls.iter().map(|m| m.name.clone()).collect());
            }
            let budget = r_energy * em.model_energy_exact()?;
            let sol = select::solve_exact(&problem, budget)?;
            let picked: Vec<String> = sol
                .picks
                .iter()
                .enumerate()
                .map(|(k, &i)| names[k][i].clone())
                .collect();
            Ok(ComputeOut::Other(codec::solution_json(&sol, &picked)))
        }
        Op::Health
        | Op::Status
        | Op::Shutdown
        | Op::Reconfigure { .. }
        | Op::ArtifactGet { .. }
        | Op::ArtifactPut { .. } => {
            unreachable!("inline ops never reach the batcher")
        }
    }
}

/// Answer one artifact replication op from the daemon's **local** store
/// tier (disk I/O only — no `Session`, so it runs inline on the reader
/// thread like `status`; and `get_local`/`envelope_local` never consult
/// this daemon's own peers, so fleet fetches cannot cycle).
fn handle_artifact(shared: &Shared, req: &Request) -> Result<Json> {
    let store =
        shared.store.as_ref().context("artifact store is disabled on this daemon (no_cache)")?;
    match &req.op {
        Op::ArtifactGet { kind, fingerprint } => {
            let fp = crate::store::Fingerprint::from_hex(fingerprint)
                .with_context(|| format!("malformed fingerprint {fingerprint:?}"))?;
            anyhow::ensure!(crate::store::kind_is_safe(kind), "unsafe store kind {kind:?}");
            let env = store.envelope_local(kind, fp);
            Ok(Json::obj().with("envelope", env.unwrap_or(Json::Null)))
        }
        Op::ArtifactPut { kind, envelope } => {
            let fp = store.put_envelope(kind, envelope)?;
            Ok(Json::obj().with("fingerprint", fp.hex()))
        }
        _ => unreachable!("handle_artifact only takes artifact ops"),
    }
}

/// Config keys a `reconfigure` delta may touch: inputs of the mobile
/// stage-graph tail (select + calibrate). Anything upstream of those
/// stages (model identity, seed, estimation, training, artifact layout)
/// or process-level (jobs, cache, peers) requires a restart and is
/// rejected, so a live daemon can never drift away from its immutable
/// warm state.
const RECONFIGURE_KEYS: &[&str] =
    &["r_energy", "calib_epochs", "calib_samples", "calib_lr", "q_step", "q_max", "sweep_metric"];

/// Apply one `reconfigure` delta: fold the allowed keys into the entry's
/// config, resolve the operating point the new config names — in-memory
/// Pareto front first, then cached `select`/`calibrate` store artifacts,
/// then a full activation on a scratch session — and atomically swap it
/// in. Runs inline on the reader thread (like the artifact ops); the
/// entry's config mutex serializes concurrent reconfigures per model, and
/// the swap takes effect at the next dispatcher wave.
fn handle_reconfigure(shared: &Shared, req: &Request) -> Result<Json> {
    let Op::Reconfigure { delta } = &req.op else {
        unreachable!("handle_reconfigure only takes reconfigure ops")
    };
    let entry = shared.registry.get(req.model.as_deref())?;
    let pairs = delta.as_obj().context("'delta' must be an object of config overrides")?;

    let t0 = Instant::now();
    let mut cfg_guard = entry.cfg.lock().unwrap();
    let mut cfg = cfg_guard.clone();
    for (k, v) in pairs {
        anyhow::ensure!(
            RECONFIGURE_KEYS.contains(&k.as_str()),
            "'{k}' is not live-reconfigurable (allowed: {})",
            RECONFIGURE_KEYS.join("|")
        );
        let s = match v {
            Json::Str(s) => s.clone(),
            Json::Num(n) => format!("{n}"),
            other => anyhow::bail!("delta key '{k}': unsupported value {other}"),
        };
        crate::config::apply_kv(&mut cfg, k, &s)?;
    }

    // the fingerprint chain from the entry's immutable anchors names the
    // operating point the new config asks for — before any work happens
    let est_fp =
        pipeline::estimate_fingerprint(&cfg, entry.lib_fp, entry.manifest_hash, entry.params_hash);
    let cal_fp = pipeline::calibrate_fingerprint(&cfg, pipeline::select_fingerprint(&cfg, est_fp));
    let manifest = &entry.session.art.manifest;

    let mut swapped = false;
    let (act, source, stages) = if entry.active_fingerprint() == Some(cal_fp) {
        let cur = entry.active_selection().context("active selection vanished")?;
        (cur, "active", Vec::new())
    } else if let Some(point) = entry.pareto.as_ref().and_then(|f| f.lookup_fp(cal_fp)) {
        // pure cache hit: rehydrate from the precomputed front and swap
        entry.pareto_hits.fetch_add(1, Ordering::Relaxed);
        let act = Arc::new(point.to_active(&entry.library, manifest)?);
        let stages = vec![
            StageRun { stage: "estimate", fingerprint: est_fp.hex(), hit: Some(true), secs: 0.0 },
            StageRun {
                stage: "select",
                fingerprint: act.select_fp.hex(),
                hit: Some(true),
                secs: 0.0,
            },
            StageRun { stage: "calibrate", fingerprint: cal_fp.hex(), hit: Some(true), secs: 0.0 },
        ];
        entry.swap_active(act.clone());
        swapped = true;
        (act, "pareto", stages)
    } else {
        entry.pareto_misses.fetch_add(1, Ordering::Relaxed);
        let cached = shared
            .store
            .as_ref()
            .and_then(|s| pipeline::active::activate_cached(s, &entry.library, manifest, est_fp, &cfg));
        let (activation, source) = match cached {
            Some(a) => (a, "store"),
            None => {
                // full fallback: run the mobile stages on a scratch
                // session, so the shared serving session stays immutable
                // and the batcher keeps scoring lock-free throughout
                let mut scratch = pipeline::warm_session(shared.rt.clone(), &cfg)
                    .with_context(|| format!("warming scratch session for '{}'", entry.key))?;
                let a = pipeline::active::activate(&mut scratch, &entry.library, entry.lib_fp, &cfg)?;
                (a, "computed")
            }
        };
        let act = Arc::new(activation.selection);
        entry.swap_active(act.clone());
        swapped = true;
        (act, source, activation.stages)
    };
    *cfg_guard = cfg.clone();
    drop(cfg_guard);

    // the immutable half never moves on this path: report it as reused
    // alongside the mobile stages' hit/miss records
    let mut stage_arr = Json::arr();
    stage_arr.push(
        Json::obj()
            .with("stage", "library")
            .with("fingerprint", entry.lib_fp.hex().as_str())
            .with("status", "reused"),
    );
    stage_arr.push(
        Json::obj()
            .with("stage", "train")
            .with(
                "fingerprint",
                pipeline::train_fingerprint(&cfg, entry.params_hash).hex().as_str(),
            )
            .with("status", "reused"),
    );
    for run in &stages {
        stage_arr.push(
            Json::obj()
                .with("stage", run.stage)
                .with("fingerprint", run.fingerprint.as_str())
                .with("status", run.status()),
        );
    }
    Ok(Json::obj()
        .with("model", entry.key.as_str())
        .with("selection", cal_fp.hex().as_str())
        .with("r_energy", cfg.r_energy)
        .with("source", source)
        .with("swapped", swapped)
        .with("energy_ratio_exact", act.energy_ratio_exact)
        .with("names", act.names.clone())
        .with("stages", stage_arr)
        .with("secs", t0.elapsed().as_secs_f64()))
}

/// Per-connection reader: decode lines through the bounded reader and the
/// zero-alloc wire path, answer `status`/`shutdown` inline, enqueue
/// compute ops (shedding when the queue is full). A paired writer thread
/// owns the outbound half so batcher waves and inline answers can
/// interleave safely; its bounded channel plus the write timeout are what
/// evict slow clients.
fn serve_connection(
    stream: TcpStream,
    shared: &Shared,
    client_id: u64,
    _guard: admission::ConnGuard,
) {
    use std::io::{BufReader, BufWriter, Write};

    let conn = stream.try_clone().ok().map(|s| Arc::new(ConnHandle::new(s)));
    let Ok(write_half) = stream.try_clone() else { return };
    let _ = write_half.set_write_timeout(Some(Duration::from_millis(shared.write_timeout_ms)));
    let (tx, rx) = mpsc::sync_channel::<String>(REPLY_BUFFER);
    let writer_conn = conn.clone();
    let writer_fault = shared.fault.clone();
    let writer = std::thread::spawn(move || {
        let mut w = BufWriter::new(write_half);
        for line in rx {
            // injected wire faults on the response path: the schedule is
            // deterministic per plan, so the chaos suite replays exactly
            if let Some(f) = &writer_fault {
                match f.response_action() {
                    fault::ResponseAction::Deliver => {}
                    fault::ResponseAction::Delay(d) => std::thread::sleep(d),
                    fault::ResponseAction::Drop => continue,
                    fault::ResponseAction::Truncate => {
                        let _ = w
                            .write_all(&line.as_bytes()[..line.len() / 2])
                            .and_then(|_| w.flush());
                        if let Some(c) = &writer_conn {
                            c.evict();
                        }
                        break;
                    }
                }
            }
            if w.write_all(line.as_bytes())
                .and_then(|_| w.write_all(b"\n"))
                .and_then(|_| w.flush())
                .is_err()
            {
                // flush timeout or reset: tear the connection down so the
                // reader unblocks too (slow-client eviction)
                if let Some(c) = &writer_conn {
                    c.evict();
                }
                break;
            }
        }
    });

    let mut reader = BufReader::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    loop {
        match wire::read_line_bounded(&mut reader, &mut buf, shared.max_line) {
            Err(_) => break, // reset / evicted
            Ok(wire::LineRead::Eof) => break,
            Ok(wire::LineRead::Oversized) => {
                shared.stats.oversized.fetch_add(1, Ordering::Relaxed);
                shared.stats.errors.fetch_add(1, Ordering::Relaxed);
                let msg = format!("request line exceeds {} bytes", shared.max_line);
                if tx.send(wire::err_line(-1, &msg)).is_err() {
                    break;
                }
                continue;
            }
            Ok(wire::LineRead::Line) => {}
        }
        let Ok(text) = std::str::from_utf8(&buf) else {
            shared.stats.errors.fetch_add(1, Ordering::Relaxed);
            if tx.send(wire::err_line(-1, "request line is not valid UTF-8")).is_err() {
                break;
            }
            continue;
        };
        let trimmed = text.trim();
        if trimmed.is_empty() {
            continue;
        }
        match wire::decode_line(trimmed) {
            Err(e) => {
                shared.stats.errors.fetch_add(1, Ordering::Relaxed);
                let id = codec::request_id(trimmed);
                if tx.send(wire::err_line(id, &format!("{e:#}"))).is_err() {
                    break;
                }
            }
            Ok(req) => {
                if let Some(f) = &shared.fault {
                    if f.note_request() {
                        // kill-after-N fired: drain and exit, exactly like
                        // an operator-initiated shutdown
                        shared.begin_shutdown();
                    }
                }
                match req.op {
                Op::Health => {
                    let body = health::health_json(
                        shared.generation,
                        &shared.registry.keys(),
                        shared.batcher.pending(),
                        shared.waves.p99_ms(),
                    );
                    if tx.send(wire::ok_line(req.id, &body)).is_err() {
                        break;
                    }
                }
                Op::Status => {
                    let line = wire::ok_line(req.id, &shared.status_json());
                    if tx.send(line).is_err() {
                        break;
                    }
                }
                Op::Shutdown => {
                    let line = wire::ok_line(req.id, &Json::obj().with("stopping", true));
                    let sent = tx.send(line);
                    shared.begin_shutdown();
                    if sent.is_err() {
                        break;
                    }
                }
                Op::ArtifactGet { .. } | Op::ArtifactPut { .. } => {
                    shared.stats.count(&req.op);
                    let line = match handle_artifact(shared, &req) {
                        Ok(result) => wire::ok_line(req.id, &result),
                        Err(e) => {
                            shared.stats.errors.fetch_add(1, Ordering::Relaxed);
                            wire::err_line(req.id, &format!("{e:#}"))
                        }
                    };
                    if tx.send(line).is_err() {
                        break;
                    }
                }
                Op::Reconfigure { .. } => {
                    // inline, not batched: the swap must not wait behind the
                    // wave it is about to supersede, and wave snapshots make
                    // racing with in-flight evaluates safe
                    shared.stats.count(&req.op);
                    let line = match handle_reconfigure(shared, &req) {
                        Ok(result) => wire::ok_line(req.id, &result),
                        Err(e) => {
                            shared.stats.errors.fetch_add(1, Ordering::Relaxed);
                            wire::err_line(req.id, &format!("{e:#}"))
                        }
                    };
                    if tx.send(line).is_err() {
                        break;
                    }
                }
                _ => {
                    shared.stats.count(&req.op);
                    let id = req.id;
                    let job = Job {
                        client: client_id,
                        request: req,
                        sink: ReplySink::Line { tx: tx.clone(), conn: conn.clone() },
                    };
                    match shared.batcher.enqueue(job) {
                        batcher::Enqueue::Ok => {}
                        batcher::Enqueue::Shed => {
                            shared.stats.shed.fetch_add(1, Ordering::Relaxed);
                            let line = wire::shed_line(id, admission::OVERLOADED_QUEUE);
                            if tx.send(line).is_err() {
                                break;
                            }
                        }
                        batcher::Enqueue::Closed => {
                            // shed, not a hard error: a retry against the
                            // fleet (or this address post-restart) succeeds,
                            // and the router fails over on this message
                            shared.stats.shed.fetch_add(1, Ordering::Relaxed);
                            if tx.send(wire::shed_line(id, admission::DRAINING)).is_err() {
                                break;
                            }
                        }
                    }
                }
            }
            }
        }
    }
    drop(tx);
    let _ = writer.join();
}
