//! Fleet router — one endpoint in front of N sharded serve daemons.
//!
//! The router presents the same two front doors as a single daemon (NDJSON
//! lines + the HTTP/1.1 gateway) and forwards every compute and artifact
//! request to the shard that owns its `<model>/<cfg>` key on the
//! consistent-hash [`Ring`]. Forwarding is byte-transparent on the NDJSON
//! path: the client's request line goes to the shard verbatim and the
//! shard's response line comes back verbatim, so routed responses are
//! byte-identical to a direct single-node call (`tests/serve_fleet.rs`
//! pins this).
//!
//! # Pools, failure, and shed semantics
//!
//! Each shard gets one bounded connection [`Pool`] (at most
//! `pool_per_shard` concurrent leases; idle connections are reused). A
//! transport failure — connect refused, write/read error, response
//! timeout — puts the shard on a short cooldown and the request fails over
//! to the ring's successor shards in order. Overload semantics are
//! preserved end to end, never hidden:
//!
//! * a shard's *request* shed (`"shed":true` with the request id) relays
//!   verbatim — the client sees exactly what the shard said;
//! * a shard's *connection* refusal (the gate's `id:-1` line) is
//!   translated to a shed response carrying the request's id, because the
//!   refusal applies to the router↔shard connection, not the client's;
//! * a failover shard that does not serve the key's model answers
//!   "unknown model" — the router translates that to a shed too (the
//!   owning shard is down; the request is retryable, not defective);
//! * when every shard is unreachable the router sheds explicitly rather
//!   than hanging.
//!
//! # Liveness-driven membership
//!
//! A prober thread dials every shard's `health` op on a jittered interval
//! and folds the answers into a [`Membership`] view (`Up` → `Suspect` on
//! one miss → `Down` on the second). Routing filters the ring successor
//! order through the current view, so requests stop dialing a dead shard
//! as soon as the prober notices — the failure-triggered down-cooldown
//! remains only as a fast-path backstop between probes. A shard whose
//! request queue is draining for shutdown answers a `"shed":true` line
//! carrying [`admission::DRAINING`]; the router treats that as a failover
//! signal (try the successor) rather than relaying it, which is what makes
//! rolling restarts invisible to clients.
//!
//! # Request hedging
//!
//! When the owner's rolling p99 (router-observed round trips) exceeds
//! `hedge_threshold` × the fleet median, a hedgeable request (evaluate /
//! energy / select — pure computations, bit-identical across replicas by
//! the store contract) is duplicated to the first live successor and the
//! first non-shed answer wins. The loser's reply is drained and counted,
//! never delivered, so clients still see exactly one response per id.
//!
//! # Fleet-wide reconfigure
//!
//! `reconfigure` is a broadcast, not a routed request: the delta goes to
//! every live shard in turn (never hedged, never failed over — it mutates
//! shard state) and the router aggregates the per-shard results, including
//! whether all shards agreed on the resulting selection fingerprint. One
//! shard rejecting the delta is relayed verbatim (all shards run the same
//! validation); one shard being unreachable is an error, not a silent
//! partial apply.
//!
//! `status` is answered by the router itself (fleet view: per-shard
//! forward counts, liveness, latency, and each live shard's per-model
//! active selection + Pareto counters). `shutdown` stops the router
//! only — shards are independent processes with their own lifecycles.

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::json::Json;

use super::codec::{self, Op, Request, PROTOCOL};
use super::health::{self, Liveness, Membership, ProbeReport};
use super::http::{error_body_into, write_response, Outcome as HttpOutcome};
use super::ring::Ring;
use super::{admission, wire};

/// Cap on one forwarded response line (artifact envelopes can be large).
const MAX_FORWARD_RESPONSE: usize = 64 << 20;

/// Request id on prober-originated `health` lines (never echoes a client).
const PROBE_ID: i64 = -7;

/// Rolling round-trip samples a pool must hold before its p99 may trigger
/// hedging (a couple of slow cold calls should not).
const HEDGE_MIN_SAMPLES: usize = 8;

/// Shed message when no shard could answer a request.
pub const ALL_SHARDS_DOWN: &str = "no shard reachable for this key; retry shortly";

/// Shed message when the key's owning shard is down and the failover
/// shard does not serve the model.
pub const OWNER_DOWN: &str = "owning shard is unavailable; retry shortly";

/// Router configuration (CLI `fames serve route=...`).
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// NDJSON bind address; port 0 asks the OS for a free port.
    pub addr: String,
    /// Optional HTTP/1.1 front door bind address.
    pub http_addr: Option<String>,
    /// Shard NDJSON addresses — the ring's membership, order-insensitive.
    pub shards: Vec<String>,
    /// Most concurrent router→shard connections per shard.
    pub pool_per_shard: usize,
    /// Admission: most simultaneously served client connections.
    pub max_conns: usize,
    /// Most bytes one client request line (or HTTP body) may carry.
    pub max_line: usize,
    /// Per-flush write timeout toward clients (ms).
    pub write_timeout_ms: u64,
    /// Shard TCP connect timeout (ms).
    pub connect_timeout_ms: u64,
    /// Shard request round-trip timeout (ms) — also the pool-lease wait.
    pub io_timeout_ms: u64,
    /// How long a shard stays out of rotation after a transport failure
    /// (ms). Membership supersedes this for liveness; the cooldown remains
    /// the fast-path backstop between probes, and its value is the floor
    /// of the probe interval.
    pub down_cooldown_ms: u64,
    /// Membership probe interval (ms); the effective period is
    /// `max(probe_interval_ms, down_cooldown_ms)`, jittered per shard.
    pub probe_interval_ms: u64,
    /// Hedge a request when the owner's rolling p99 exceeds this multiple
    /// of the fleet median round trip. `<= 0` disables hedging.
    pub hedge_threshold: f64,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            addr: "127.0.0.1:4270".to_string(),
            http_addr: None,
            shards: Vec::new(),
            pool_per_shard: 16,
            max_conns: 1024,
            max_line: 1 << 20,
            write_timeout_ms: 10_000,
            connect_timeout_ms: 500,
            io_timeout_ms: 10_000,
            down_cooldown_ms: 500,
            probe_interval_ms: 500,
            hedge_threshold: 3.0,
        }
    }
}

/// Router-side request counters (status + bench assertions).
#[derive(Default)]
pub struct RouterStats {
    /// Requests answered by a shard (primary or failover).
    pub forwarded: AtomicU64,
    /// Requests that failed over past their primary shard.
    pub rerouted: AtomicU64,
    /// Requests the router itself shed (all shards down, owner down,
    /// translated connection refusals).
    pub shed: AtomicU64,
    /// Malformed requests bounced at the router.
    pub errors: AtomicU64,
    /// Requests duplicated to a successor because the owner looked slow.
    pub hedged: AtomicU64,
    /// Hedged requests whose *successor* answer was delivered.
    pub hedge_wins: AtomicU64,
    /// Hedge loser replies drained (counted, never delivered).
    pub hedge_drained: AtomicU64,
    /// Membership probes sent.
    pub probes: AtomicU64,
}

/// One shard's bounded connection pool. Leases are capped; idle
/// connections are reused; a transport failure drops the connection (a
/// half-written stream can never be reused — it would desync request and
/// response framing) and puts the shard on a cooldown.
struct Pool {
    addr: String,
    cap: usize,
    connect_timeout: Duration,
    io_timeout: Duration,
    cooldown: Duration,
    state: Mutex<PoolState>,
    cv: Condvar,
    forwarded: AtomicU64,
    /// Rolling router-observed round-trip latencies (the hedging signal).
    window: health::WaveWindow,
}

#[derive(Default)]
struct PoolState {
    idle: Vec<TcpStream>,
    leased: usize,
    down_until: Option<Instant>,
}

/// Lease accounting guard: always returns the slot (and optionally a
/// healthy connection) to the pool, whatever path exits `round_trip`.
struct Permit<'a> {
    pool: &'a Pool,
    put_back: Option<TcpStream>,
}

impl Drop for Permit<'_> {
    fn drop(&mut self) {
        let mut st = self.pool.state.lock().unwrap();
        st.leased -= 1;
        if let Some(s) = self.put_back.take() {
            if st.idle.len() < self.pool.cap {
                st.idle.push(s);
            }
        }
        drop(st);
        self.pool.cv.notify_one();
    }
}

impl Pool {
    fn new(
        addr: String,
        cap: usize,
        connect_timeout: Duration,
        io_timeout: Duration,
        cooldown: Duration,
    ) -> Pool {
        Pool {
            addr,
            cap: cap.max(1),
            connect_timeout,
            io_timeout,
            cooldown,
            state: Mutex::new(PoolState::default()),
            cv: Condvar::new(),
            forwarded: AtomicU64::new(0),
            window: health::WaveWindow::new(128),
        }
    }

    fn is_down(&self) -> bool {
        matches!(self.state.lock().unwrap().down_until, Some(t) if Instant::now() < t)
    }

    /// Acquire a lease (bounded by `cap`, waiting at most `io_timeout`)
    /// plus an idle connection when one is available.
    fn acquire(&self) -> Result<(Permit<'_>, Option<TcpStream>)> {
        let mut st = self.state.lock().unwrap();
        let deadline = Instant::now() + self.io_timeout;
        loop {
            if let Some(t) = st.down_until {
                if Instant::now() < t {
                    bail!("shard {} is cooling down after a failure", self.addr);
                }
            }
            if st.leased < self.cap {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                bail!("connection pool to shard {} is exhausted", self.addr);
            }
            let (guard, _) = self.cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
        st.leased += 1;
        let idle = st.idle.pop();
        Ok((Permit { pool: self, put_back: None }, idle))
    }

    fn connect(&self) -> Result<TcpStream> {
        let sock: SocketAddr = self
            .addr
            .to_socket_addrs()
            .with_context(|| format!("resolving shard address {}", self.addr))?
            .next()
            .with_context(|| format!("shard address {} resolves to nothing", self.addr))?;
        let s = TcpStream::connect_timeout(&sock, self.connect_timeout)
            .with_context(|| format!("connecting to shard {}", self.addr))?;
        let _ = s.set_nodelay(true);
        let _ = s.set_read_timeout(Some(self.io_timeout));
        let _ = s.set_write_timeout(Some(self.io_timeout));
        Ok(s)
    }

    /// One request line → one response line, with the round trip recorded
    /// into the rolling latency window (successful trips only — failures
    /// feed the cooldown and the membership prober instead).
    fn round_trip(&self, line: &str) -> Result<String> {
        let t0 = Instant::now();
        let out = self.round_trip_inner(line);
        if out.is_ok() {
            self.window.record(t0.elapsed().as_secs_f64() * 1e3);
        }
        out
    }

    /// A stale pooled connection (closed by the shard since it was pooled)
    /// is retried once on a fresh connection before the shard is declared
    /// down.
    fn round_trip_inner(&self, line: &str) -> Result<String> {
        let (mut permit, idle) = self.acquire()?;
        if let Some(s) = idle {
            if let Ok(resp) = exchange(&s, line) {
                if reusable(&resp) {
                    permit.put_back = Some(s);
                }
                self.mark_up();
                self.forwarded.fetch_add(1, Ordering::Relaxed);
                return Ok(resp);
            }
            // fall through: the pooled connection was stale
        }
        let s = match self.connect() {
            Ok(s) => s,
            Err(e) => {
                self.mark_down();
                return Err(e);
            }
        };
        match exchange(&s, line) {
            Ok(resp) => {
                if reusable(&resp) {
                    permit.put_back = Some(s);
                }
                self.mark_up();
                self.forwarded.fetch_add(1, Ordering::Relaxed);
                Ok(resp)
            }
            Err(e) => {
                self.mark_down();
                Err(e).with_context(|| format!("forwarding to shard {}", self.addr))
            }
        }
    }

    fn mark_down(&self) {
        let mut st = self.state.lock().unwrap();
        st.down_until = Some(Instant::now() + self.cooldown);
        st.idle.clear(); // pooled connections to a failing shard are suspect
        drop(st);
        self.cv.notify_all();
    }

    fn mark_up(&self) {
        self.state.lock().unwrap().down_until = None;
    }
}

/// Write one line, read one line. Serial per connection by construction
/// (one lease = one in-flight request), so a fresh `BufReader` cannot
/// strand buffered bytes.
fn exchange(stream: &TcpStream, line: &str) -> std::io::Result<String> {
    let mut w = stream;
    w.write_all(line.as_bytes())?;
    w.write_all(b"\n")?;
    let mut reader = BufReader::new(stream);
    let mut buf = Vec::new();
    match wire::read_line_bounded(&mut reader, &mut buf, MAX_FORWARD_RESPONSE)? {
        wire::LineRead::Line => String::from_utf8(buf)
            .map_err(|_| std::io::Error::new(std::io::ErrorKind::InvalidData, "non-UTF-8 response")),
        wire::LineRead::Eof => {
            Err(std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "shard closed connection"))
        }
        wire::LineRead::Oversized => {
            Err(std::io::Error::new(std::io::ErrorKind::InvalidData, "oversized shard response"))
        }
    }
}

/// May this shard connection serve another request? A connection-level
/// refusal (`id:-1` shed) is followed by the shard closing the socket, so
/// it must not go back in the pool. (Substring check: a false negative
/// just costs one reconnect; a false positive is repaired by the stale
/// retry in `round_trip`.)
fn reusable(resp: &str) -> bool {
    !resp.contains("\"id\":-1,\"ok\":false")
}

/// Did the shard answer with the gate's connection-refusal line?
fn is_conn_refusal(resp: &str) -> bool {
    if !resp.contains("\"id\":-1,\"ok\":false") {
        return false;
    }
    let Ok(j) = Json::parse(resp) else { return false };
    j.get("id").and_then(|v| v.as_i64()).map(|id| id == -1).unwrap_or(false)
        && j.get("shed").and_then(|v| v.as_bool()).unwrap_or(false)
}

/// Did the shard answer "I'm draining for shutdown"? That shed carries the
/// request's id but is a *failover* signal to the router: the successor
/// (warm, by replication) answers instead, so a rolling restart never
/// surfaces to the client.
fn is_draining(resp: &str) -> bool {
    if !resp.contains(admission::DRAINING) {
        return false;
    }
    let Ok(j) = Json::parse(resp) else { return false };
    !j.get("ok").and_then(|v| v.as_bool()).unwrap_or(true)
        && j.get("shed").and_then(|v| v.as_bool()).unwrap_or(false)
        && j.get("error").ok().and_then(|v| v.as_str().ok().map(str::to_string)).as_deref()
            == Some(admission::DRAINING)
}

/// Extract the error message iff this is an "unknown model" rejection.
fn unknown_model_error(resp: &str) -> Option<String> {
    if !resp.contains("unknown model") {
        return None;
    }
    let j = Json::parse(resp).ok()?;
    if j.get("ok").and_then(|v| v.as_bool()).ok()? {
        return None;
    }
    let err = j.get("error").ok()?.as_str().ok()?;
    err.starts_with("unknown model").then(|| err.to_string())
}

/// State shared by the router's accept loops and connection threads.
struct RouterShared {
    ring: Ring,
    pools: Vec<Pool>,
    stats: RouterStats,
    membership: Membership,
    stop: AtomicBool,
    addr: SocketAddr,
    http_addr: Option<SocketAddr>,
    started: Instant,
    gate: Arc<admission::Gate>,
    max_line: usize,
    write_timeout_ms: u64,
    probe_period: Duration,
    probe_timeout: Duration,
    hedge_threshold: f64,
}

impl RouterShared {
    /// Route one raw request line to its shard fleet and return the
    /// response line to relay. Always answers: failures shed explicitly.
    ///
    /// The ring successor order is filtered through the current membership
    /// view first, so `Down` shards are never dialed; `hedgeable` requests
    /// may additionally race the owner against its first live successor
    /// when the owner's tail looks slow.
    fn forward(self: &Arc<Self>, key: &str, id: i64, line: &str, hedgeable: bool) -> String {
        let view = self.membership.view();
        let order = view.filter_order(&self.ring.successors(key));
        if order.is_empty() {
            self.stats.shed.fetch_add(1, Ordering::Relaxed);
            return wire::shed_line(id, ALL_SHARDS_DOWN);
        }
        if hedgeable && order.len() >= 2 && self.should_hedge(order[0]) {
            if let Some(resp) = self.hedged_round_trip(order[0], order[1], line) {
                self.stats.forwarded.fetch_add(1, Ordering::Relaxed);
                return resp;
            }
            // both legs shed or failed: fall through to the sequential
            // walk (the ops are pure, so a re-send is harmless)
        }
        let mut failed_over = false;
        for &shard in &order {
            let resp = match self.pools[shard].round_trip(line) {
                Ok(r) => r,
                Err(_) => {
                    failed_over = true;
                    continue;
                }
            };
            if is_draining(&resp) {
                // the shard is shutting down; its replica answers instead
                failed_over = true;
                continue;
            }
            self.stats.forwarded.fetch_add(1, Ordering::Relaxed);
            if is_conn_refusal(&resp) {
                // the shard refused the router's *connection*; re-scope
                // the shed to this request so the client can retry it
                self.stats.shed.fetch_add(1, Ordering::Relaxed);
                return wire::shed_line(id, admission::OVERLOADED_CONNS);
            }
            if failed_over {
                self.stats.rerouted.fetch_add(1, Ordering::Relaxed);
                if unknown_model_error(&resp).is_some() {
                    // the failover shard does not serve this key — the
                    // owner is down, which is overload, not a bad request
                    self.stats.shed.fetch_add(1, Ordering::Relaxed);
                    return wire::shed_line(id, OWNER_DOWN);
                }
            }
            return resp;
        }
        self.stats.shed.fetch_add(1, Ordering::Relaxed);
        wire::shed_line(id, ALL_SHARDS_DOWN)
    }

    /// Fan one `reconfigure` out to every live shard and aggregate the
    /// answers. A tier change is fleet-wide state, not a routed
    /// computation: every shard holding a replica of the model must swap,
    /// or routed traffic would flip between operating points depending on
    /// which replica answers. Never hedged and never failed over — the op
    /// mutates shard state, so a shard that could not apply it must
    /// surface in the response rather than be papered over.
    fn broadcast_reconfigure(self: &Arc<Self>, id: i64, line: &str) -> String {
        let view = self.membership.view();
        let all: Vec<usize> = (0..self.pools.len()).collect();
        let live = view.filter_order(&all);
        if live.is_empty() {
            self.stats.shed.fetch_add(1, Ordering::Relaxed);
            return wire::shed_line(id, ALL_SHARDS_DOWN);
        }
        let mut shards = Json::arr();
        let mut selection: Option<String> = None;
        let mut agreed = true;
        for &i in &live {
            let addr = self.ring.shards()[i].as_str();
            let resp = match self.pools[i].round_trip(line) {
                Ok(r) => r,
                Err(e) => {
                    self.stats.shed.fetch_add(1, Ordering::Relaxed);
                    return wire::err_line(
                        id,
                        &format!("reconfigure did not reach shard {addr}: {e:#}"),
                    );
                }
            };
            if is_conn_refusal(&resp) {
                self.stats.shed.fetch_add(1, Ordering::Relaxed);
                return wire::shed_line(id, admission::OVERLOADED_CONNS);
            }
            let Ok(j) = Json::parse(&resp) else {
                return wire::err_line(id, &format!("shard {addr} answered with invalid JSON"));
            };
            if !j.get("ok").and_then(|v| v.as_bool()).unwrap_or(false) {
                // relay the first rejection verbatim: every shard runs the
                // same delta validation, so one rejection speaks for all
                return resp;
            }
            let result = j.get("result").ok().cloned().unwrap_or(Json::Null);
            let sel = result
                .get("selection")
                .ok()
                .and_then(|v| v.as_str().ok())
                .map(str::to_string);
            match (&selection, &sel) {
                (None, Some(s)) => selection = Some(s.clone()),
                (Some(a), Some(b)) if a != b => agreed = false,
                _ => {}
            }
            shards.push(Json::obj().with("addr", addr).with("result", result));
        }
        self.stats.forwarded.fetch_add(1, Ordering::Relaxed);
        let mut out = Json::obj()
            .with("agreed", agreed)
            .with("fleet", live.len())
            .with("shards", shards);
        if let Some(s) = selection {
            out = out.with("selection", s);
        }
        wire::ok_line(id, &out)
    }

    /// Should a request owned by `owner` be hedged? Yes when the owner's
    /// rolling p99 exceeds `hedge_threshold` × the fleet median (over
    /// pools with data), with a minimum sample count so cold starts don't
    /// trigger it.
    fn should_hedge(&self, owner: usize) -> bool {
        if self.hedge_threshold <= 0.0 {
            return false;
        }
        let pool = &self.pools[owner];
        if pool.window.len() < HEDGE_MIN_SAMPLES {
            return false;
        }
        let mut p99s: Vec<f64> = self
            .pools
            .iter()
            .filter(|p| !p.window.is_empty())
            .map(|p| p.window.p99_ms())
            .collect();
        if p99s.len() < 2 {
            return false; // no fleet to compare against
        }
        p99s.sort_by(|a, b| a.total_cmp(b));
        let median = p99s[(p99s.len() - 1) / 2];
        median > 0.0 && pool.window.p99_ms() > self.hedge_threshold * median
    }

    /// Race `owner` against `successor` for one request line and deliver
    /// the first useful answer. The loser's reply is drained by its own
    /// thread (its send fails once a winner is taken) and counted — never
    /// delivered, so the client sees exactly one response per id. Safe
    /// because hedgeable ops are pure and replicas are bit-identical.
    /// `None` when both legs shed, drained, or failed.
    fn hedged_round_trip(self: &Arc<Self>, owner: usize, successor: usize, line: &str) -> Option<String> {
        self.stats.hedged.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel::<(usize, String)>();
        for (leg, shard) in [(0usize, owner), (1usize, successor)] {
            let me = self.clone();
            let tx = tx.clone();
            let line = line.to_string();
            std::thread::spawn(move || {
                if let Ok(resp) = me.pools[shard].round_trip(&line) {
                    if tx.send((leg, resp)).is_err() {
                        me.stats.hedge_drained.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
        drop(tx);
        while let Ok((leg, resp)) = rx.recv() {
            if is_conn_refusal(&resp) || is_draining(&resp) {
                continue; // this leg can't answer; wait for the other
            }
            if leg == 1 && unknown_model_error(&resp).is_some() {
                continue; // cold successor without the replica: owner only
            }
            if leg == 1 {
                self.stats.hedge_wins.fetch_add(1, Ordering::Relaxed);
            }
            return Some(resp);
        }
        None
    }

    fn status_json(&self) -> Json {
        let view = self.membership.view();
        let mut shards = Json::arr();
        for (i, p) in self.pools.iter().enumerate() {
            let mut entry = Json::obj()
                .with("addr", self.ring.shards()[i].as_str())
                .with("forwarded", p.forwarded.load(Ordering::Relaxed) as usize)
                .with("down", p.is_down())
                .with("liveness", view.liveness(i).as_str())
                .with("p99_ms", p.window.p99_ms());
            // fleet view of adaptive serving: each live shard's per-model
            // active selection fingerprint and Pareto counters (probe-style
            // direct dial — best-effort, omitted when unreachable)
            if view.liveness(i) != Liveness::Down {
                if let Some(models) = shard_models(&p.addr, self.probe_timeout) {
                    entry = entry.with("models", models);
                }
            }
            shards.push(entry);
        }
        Json::obj()
            .with("protocol", PROTOCOL)
            .with("role", "router")
            .with("shards", shards)
            .with("uptime_secs", self.started.elapsed().as_secs_f64())
            .with(
                "membership",
                Json::obj()
                    .with("generation", view.generation() as usize)
                    .with("probes", self.stats.probes.load(Ordering::Relaxed) as usize),
            )
            .with(
                "requests",
                Json::obj()
                    .with("forwarded", self.stats.forwarded.load(Ordering::Relaxed) as usize)
                    .with("rerouted", self.stats.rerouted.load(Ordering::Relaxed) as usize)
                    .with("shed", self.stats.shed.load(Ordering::Relaxed) as usize)
                    .with("errors", self.stats.errors.load(Ordering::Relaxed) as usize)
                    .with("hedged", self.stats.hedged.load(Ordering::Relaxed) as usize)
                    .with("hedge_wins", self.stats.hedge_wins.load(Ordering::Relaxed) as usize)
                    .with(
                        "hedge_drained",
                        self.stats.hedge_drained.load(Ordering::Relaxed) as usize,
                    ),
            )
            .with(
                "admission",
                Json::obj()
                    .with("active_conns", self.gate.active())
                    .with("max_conns", self.gate.max_conns())
                    .with("shed_conns", self.gate.shed_total() as usize),
            )
    }

    fn begin_shutdown(&self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        let _ = TcpStream::connect(self.addr);
        if let Some(addr) = self.http_addr {
            let _ = TcpStream::connect(addr);
        }
    }
}

/// A bound fleet router. `bind` is cheap (no model warming — shards own
/// that); `run` serves until a `shutdown` request.
pub struct Router {
    listener: TcpListener,
    http_listener: Option<TcpListener>,
    shared: Arc<RouterShared>,
}

impl Router {
    pub fn bind(cfg: &RouterConfig) -> Result<Router> {
        anyhow::ensure!(!cfg.shards.is_empty(), "router needs at least one shard (route=...)");
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding fames route to {}", cfg.addr))?;
        let http_listener = match &cfg.http_addr {
            Some(a) => Some(
                TcpListener::bind(a).with_context(|| format!("binding fames route http to {a}"))?,
            ),
            None => None,
        };
        let addr = listener.local_addr()?;
        let http_addr = match &http_listener {
            Some(l) => Some(l.local_addr()?),
            None => None,
        };
        let connect_timeout = Duration::from_millis(cfg.connect_timeout_ms.max(1));
        let io_timeout = Duration::from_millis(cfg.io_timeout_ms.max(1));
        let cooldown = Duration::from_millis(cfg.down_cooldown_ms.max(1));
        let pools: Vec<Pool> = cfg
            .shards
            .iter()
            .map(|a| Pool::new(a.clone(), cfg.pool_per_shard, connect_timeout, io_timeout, cooldown))
            .collect();
        let nshards = pools.len();
        Ok(Router {
            listener,
            http_listener,
            shared: Arc::new(RouterShared {
                ring: Ring::new(cfg.shards.clone()),
                pools,
                stats: RouterStats::default(),
                membership: Membership::new(nshards),
                stop: AtomicBool::new(false),
                addr,
                http_addr,
                started: Instant::now(),
                gate: Arc::new(admission::Gate::new(cfg.max_conns)),
                max_line: cfg.max_line.max(64),
                write_timeout_ms: cfg.write_timeout_ms.max(1),
                probe_period: Duration::from_millis(
                    cfg.probe_interval_ms.max(cfg.down_cooldown_ms).max(1),
                ),
                probe_timeout: connect_timeout,
                hedge_threshold: cfg.hedge_threshold,
            }),
        })
    }

    /// The bound NDJSON address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// The bound HTTP front-door address, when configured.
    pub fn http_local_addr(&self) -> Option<SocketAddr> {
        self.shared.http_addr
    }

    /// The routing ring (startup table, tests).
    pub fn ring(&self) -> &Ring {
        &self.shared.ring
    }

    /// Serve until a `shutdown` request. Mirrors `Server::run` minus the
    /// batcher: the router holds no model state, so connections forward
    /// synchronously and independently.
    pub fn run(self) -> Result<()> {
        let shared = self.shared;
        let prober = {
            let shared = shared.clone();
            std::thread::spawn(move || prober_loop(&shared))
        };
        let http_accept = self.http_listener.map(|l| {
            let shared = shared.clone();
            std::thread::spawn(move || http_accept_loop(l, &shared))
        });
        let mut conns: Vec<(std::thread::JoinHandle<()>, TcpStream)> = Vec::new();
        for stream in self.listener.incoming() {
            if shared.stop.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            conns.retain(|(h, _)| !h.is_finished());
            let Some(guard) = shared.gate.try_enter() else {
                refuse_connection(stream);
                continue;
            };
            let clone = stream.try_clone();
            let shared2 = shared.clone();
            let handle = std::thread::spawn(move || route_connection(stream, &shared2, guard));
            match clone {
                Ok(c) => conns.push((handle, c)),
                Err(_) => drop(handle),
            }
        }
        for (_, stream) in &conns {
            let _ = stream.shutdown(std::net::Shutdown::Read);
        }
        for (handle, _) in conns {
            let _ = handle.join();
        }
        if let Some(h) = http_accept {
            let _ = h.join();
        }
        let _ = prober.join();
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Membership prober
// ---------------------------------------------------------------------------

/// Dial one shard's `health` op directly (bypassing the pool so a cooldown
/// never hides a recovery) and decode the report.
fn probe_shard(addr: &str, timeout: Duration) -> Option<ProbeReport> {
    let sock: SocketAddr = addr.to_socket_addrs().ok()?.next()?;
    let s = TcpStream::connect_timeout(&sock, timeout).ok()?;
    let _ = s.set_nodelay(true);
    let _ = s.set_read_timeout(Some(timeout));
    let _ = s.set_write_timeout(Some(timeout));
    let line = Json::obj().with("id", PROBE_ID).with("op", "health").compact();
    let resp = exchange(&s, &line).ok()?;
    let j = Json::parse(&resp).ok()?;
    if !j.get("ok").and_then(|v| v.as_bool()).unwrap_or(false) {
        return None;
    }
    let r = j.get("result").ok()?;
    Some(ProbeReport {
        generation: r.get("generation").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
        queue_depth: r.get("queue_depth").and_then(|v| v.as_usize()).unwrap_or(0),
        p99_ms: r.get("p99_ms").and_then(|v| v.as_f64()).unwrap_or(0.0),
        warm: r.get("warm").and_then(|v| v.as_str_vec()).unwrap_or_default(),
    })
}

/// Dial one shard's `status` op directly (fresh connection, bypassing the
/// pool like the prober does) and extract its per-model adaptive-serving
/// view: active selection fingerprint plus Pareto counters. `None` on any
/// transport or shape problem — router status stays best-effort.
fn shard_models(addr: &str, timeout: Duration) -> Option<Json> {
    let sock: SocketAddr = addr.to_socket_addrs().ok()?.next()?;
    let s = TcpStream::connect_timeout(&sock, timeout).ok()?;
    let _ = s.set_nodelay(true);
    let _ = s.set_read_timeout(Some(timeout));
    let _ = s.set_write_timeout(Some(timeout));
    let line = Json::obj().with("id", PROBE_ID).with("op", "status").compact();
    let resp = exchange(&s, &line).ok()?;
    let j = Json::parse(&resp).ok()?;
    if !j.get("ok").and_then(|v| v.as_bool()).unwrap_or(false) {
        return None;
    }
    let mut out = Json::arr();
    for m in j.get("result").ok()?.get("models").ok()?.as_arr().ok()? {
        out.push(
            Json::obj()
                .with("key", m.get("key").ok().cloned().unwrap_or(Json::Null))
                .with(
                    "active_selection",
                    m.get("active_selection").ok().cloned().unwrap_or(Json::Null),
                )
                .with("pareto", m.get("pareto").ok().cloned().unwrap_or(Json::Null)),
        );
    }
    Some(out)
}

/// Probe one shard and fold the outcome into the membership view. On a
/// recovery the pool's failure cooldown is cleared too, so routing resumes
/// the moment the prober sees the shard again. Returns the new liveness.
fn probe_once(shared: &RouterShared, shard: usize) -> Liveness {
    shared.stats.probes.fetch_add(1, Ordering::Relaxed);
    match probe_shard(&shared.pools[shard].addr, shared.probe_timeout) {
        Some(report) => {
            if shared.membership.probe_ok(shard, report) {
                shared.pools[shard].mark_up();
            }
            Liveness::Up
        }
        None => shared.membership.probe_missed(shard),
    }
}

/// The router's probe loop: every shard, every `probe_period` (plus a
/// deterministic per-shard jitter so a fleet of routers never probes in
/// lockstep). A shard that just turned `Suspect` gets its first successor
/// probed out of band — the failover target's liveness is fresh before
/// any request needs it.
fn prober_loop(shared: &Arc<RouterShared>) {
    let n = shared.pools.len();
    let mut tick: u64 = 0;
    while !shared.stop.load(Ordering::SeqCst) {
        for shard in 0..n {
            if shared.stop.load(Ordering::SeqCst) {
                return;
            }
            if probe_once(shared, shard) == Liveness::Suspect && n > 1 {
                probe_once(shared, (shard + 1) % n);
            }
        }
        tick += 1;
        // stop-aware sleep in small slices so shutdown is prompt
        let mut left = shared.probe_period + health::probe_jitter(shared.probe_period, 0, tick);
        while left > Duration::ZERO && !shared.stop.load(Ordering::SeqCst) {
            let slice = left.min(Duration::from_millis(50));
            std::thread::sleep(slice);
            left = left.saturating_sub(slice);
        }
    }
}

/// Answer a gate-refused NDJSON connection with one shed line and close
/// (same contract as the daemon's refusal).
fn refuse_connection(stream: TcpStream) {
    std::thread::spawn(move || {
        let mut s = stream;
        let _ = s.set_write_timeout(Some(Duration::from_millis(1000)));
        let mut line = wire::shed_line(-1, admission::OVERLOADED_CONNS);
        line.push('\n');
        let _ = s.write_all(line.as_bytes());
    });
}

/// The ring key for one request: the model spec when given, else the
/// single-model convenience key (every router instance agrees, so the
/// convenience still lands on one deterministic shard).
fn route_key(req: &Request) -> &str {
    req.model.as_deref().unwrap_or("")
}

/// May this op be hedged? Only pure computations whose replicas answer
/// bit-identically — artifact ops mutate or read shard-local stores, and
/// control ops never leave the router.
fn hedgeable(op: &Op) -> bool {
    matches!(op, Op::Evaluate { .. } | Op::Energy { .. } | Op::Select { .. })
}

/// One NDJSON client connection: decode for routing, forward raw lines,
/// relay raw responses. Serial per connection — a pipelining client's
/// responses come back in request order.
fn route_connection(stream: TcpStream, shared: &Arc<RouterShared>, _guard: admission::ConnGuard) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_write_timeout(Some(Duration::from_millis(shared.write_timeout_ms)));
    let Ok(write_half) = stream.try_clone() else { return };
    let mut writer = BufWriter::new(write_half);
    let mut reader = BufReader::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    let mut send = |w: &mut BufWriter<TcpStream>, line: &str| -> bool {
        w.write_all(line.as_bytes())
            .and_then(|_| w.write_all(b"\n"))
            .and_then(|_| w.flush())
            .is_ok()
    };
    loop {
        match wire::read_line_bounded(&mut reader, &mut buf, shared.max_line) {
            Err(_) | Ok(wire::LineRead::Eof) => return,
            Ok(wire::LineRead::Oversized) => {
                shared.stats.errors.fetch_add(1, Ordering::Relaxed);
                let msg = format!("request line exceeds {} bytes", shared.max_line);
                if !send(&mut writer, &wire::err_line(-1, &msg)) {
                    return;
                }
                continue;
            }
            Ok(wire::LineRead::Line) => {}
        }
        let Ok(text) = std::str::from_utf8(&buf) else {
            shared.stats.errors.fetch_add(1, Ordering::Relaxed);
            if !send(&mut writer, &wire::err_line(-1, "request line is not valid UTF-8")) {
                return;
            }
            continue;
        };
        let trimmed = text.trim();
        if trimmed.is_empty() {
            continue;
        }
        let line = match wire::decode_line(trimmed) {
            Err(e) => {
                shared.stats.errors.fetch_add(1, Ordering::Relaxed);
                let id = codec::request_id(trimmed);
                if !send(&mut writer, &wire::err_line(id, &format!("{e:#}"))) {
                    return;
                }
                continue;
            }
            Ok(req) => match req.op {
                Op::Status => wire::ok_line(req.id, &shared.status_json()),
                Op::Reconfigure { .. } => shared.broadcast_reconfigure(req.id, trimmed),
                Op::Shutdown => {
                    let ack = wire::ok_line(req.id, &Json::obj().with("stopping", true));
                    let ok = send(&mut writer, &ack);
                    shared.begin_shutdown();
                    if !ok {
                        return;
                    }
                    continue;
                }
                _ => shared.forward(route_key(&req), req.id, trimmed, hedgeable(&req.op)),
            },
        };
        if !send(&mut writer, &line) {
            return;
        }
    }
}

// ---------------------------------------------------------------------------
// HTTP front door
// ---------------------------------------------------------------------------

/// Accept loop for the router's HTTP listener (mirrors the daemon's).
fn http_accept_loop(listener: TcpListener, shared: &Arc<RouterShared>) {
    let mut conns: Vec<(std::thread::JoinHandle<()>, TcpStream)> = Vec::new();
    for stream in listener.incoming() {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = stream else { continue };
        conns.retain(|(h, _)| !h.is_finished());
        let Some(guard) = shared.gate.try_enter() else {
            refuse_http_connection(stream);
            continue;
        };
        let clone = stream.try_clone();
        let shared2 = shared.clone();
        let handle = std::thread::spawn(move || route_http_connection(stream, &shared2, guard));
        match clone {
            Ok(c) => conns.push((handle, c)),
            Err(_) => drop(handle),
        }
    }
    for (_, stream) in &conns {
        let _ = stream.shutdown(std::net::Shutdown::Read);
    }
    for (handle, _) in conns {
        let _ = handle.join();
    }
}

fn refuse_http_connection(stream: TcpStream) {
    std::thread::spawn(move || {
        let mut s = stream;
        let _ = s.set_write_timeout(Some(Duration::from_millis(1000)));
        let mut body = String::new();
        error_body_into(
            &mut body,
            -1,
            "overloaded",
            "connection limit reached",
            admission::OVERLOADED_CONNS,
        );
        let out = HttpOutcome { status: 503, reason: "Service Unavailable", retry_after: true, close: true };
        let _ = write_response(&mut s, &out, &body);
    });
}

/// Serve one keep-alive HTTP connection on the router: parse, decode the
/// body through the wire path, re-encode as a canonical NDJSON line,
/// forward over the ring, and map the response envelope onto HTTP status
/// codes (200 / 503 shed + `Retry-After` / 404 unknown model / 400).
/// Success and error bodies are the NDJSON envelopes themselves.
fn route_http_connection(
    stream: TcpStream,
    shared: &Arc<RouterShared>,
    _guard: admission::ConnGuard,
) {
    const MAX_HEADER_LINE: usize = 8192;
    let timeout = Duration::from_millis(shared.write_timeout_ms);
    let _ = stream.set_write_timeout(Some(timeout));
    let _ = stream.set_read_timeout(Some(timeout));
    let Ok(write_half) = stream.try_clone() else { return };
    let mut writer = BufWriter::new(write_half);
    let mut reader = BufReader::new(stream);
    let mut line: Vec<u8> = Vec::new();
    let mut body_buf: Vec<u8> = Vec::new();
    let mut resp = String::with_capacity(256);

    loop {
        // -- request line --
        let req_line = loop {
            match wire::read_line_bounded(&mut reader, &mut line, MAX_HEADER_LINE) {
                Err(_) | Ok(wire::LineRead::Eof) => return,
                Ok(wire::LineRead::Oversized) => {
                    error_body_into(&mut resp, -1, "bad_request", "request line too long", "");
                    let out = HttpOutcome {
                        close: true,
                        ..HttpOutcome::err(431, "Request Header Fields Too Large")
                    };
                    let _ = write_response(&mut writer, &out, &resp);
                    return;
                }
                Ok(wire::LineRead::Line) => {}
            }
            let Ok(text) = std::str::from_utf8(&line) else { return };
            let text = text.trim_end_matches('\r');
            if !text.is_empty() {
                break text.to_string();
            }
        };
        let mut parts = req_line.split(' ').filter(|p| !p.is_empty());
        let method = parts.next().unwrap_or("").to_string();
        let target = parts.next().unwrap_or("").to_string();
        let version = parts.next().unwrap_or("HTTP/1.1").to_string();
        let path = target.split('?').next().unwrap_or("").to_string();

        // -- headers --
        let mut content_length: Option<usize> = None;
        let mut connection_close = version == "HTTP/1.0";
        let mut expect_continue = false;
        let headers_ok = loop {
            match wire::read_line_bounded(&mut reader, &mut line, MAX_HEADER_LINE) {
                Err(_) | Ok(wire::LineRead::Eof) => return,
                Ok(wire::LineRead::Oversized) => break false,
                Ok(wire::LineRead::Line) => {}
            }
            let Ok(text) = std::str::from_utf8(&line) else { break false };
            let text = text.trim_end_matches('\r');
            if text.is_empty() {
                break true;
            }
            let Some((name, value)) = text.split_once(':') else { continue };
            let value = value.trim();
            match name.trim().to_ascii_lowercase().as_str() {
                "content-length" => content_length = value.parse::<usize>().ok(),
                "connection" => {
                    let v = value.to_ascii_lowercase();
                    if v.contains("close") {
                        connection_close = true;
                    } else if v.contains("keep-alive") {
                        connection_close = false;
                    }
                }
                "expect" => expect_continue = value.to_ascii_lowercase().contains("100-continue"),
                _ => {}
            }
        };
        if !headers_ok {
            error_body_into(&mut resp, -1, "bad_request", "malformed or oversized headers", "");
            let out =
                HttpOutcome { close: true, ..HttpOutcome::err(431, "Request Header Fields Too Large") };
            let _ = write_response(&mut writer, &out, &resp);
            return;
        }

        // -- body --
        let body: String = if method == "POST" {
            let Some(len) = content_length else {
                error_body_into(&mut resp, -1, "bad_request", "POST requires Content-Length", "");
                let out = HttpOutcome { close: true, ..HttpOutcome::err(411, "Length Required") };
                let _ = write_response(&mut writer, &out, &resp);
                return;
            };
            if len > shared.max_line {
                let detail = format!("body is {len} bytes, limit is {}", shared.max_line);
                error_body_into(&mut resp, -1, "payload_too_large", "request body exceeds the line limit", &detail);
                let out = HttpOutcome { close: true, ..HttpOutcome::err(413, "Payload Too Large") };
                let _ = write_response(&mut writer, &out, &resp);
                return;
            }
            if expect_continue
                && writer.write_all(b"HTTP/1.1 100 Continue\r\n\r\n").and_then(|_| writer.flush()).is_err()
            {
                return;
            }
            body_buf.resize(len, 0);
            if reader.read_exact(&mut body_buf).is_err() {
                return;
            }
            match std::str::from_utf8(&body_buf) {
                Ok(s) => s.to_string(),
                Err(_) => {
                    error_body_into(&mut resp, -1, "bad_request", "request body is not valid UTF-8", "");
                    let out = HttpOutcome::err(400, "Bad Request");
                    if write_response(&mut writer, &out, &resp).is_err() || connection_close {
                        return;
                    }
                    continue;
                }
            }
        } else {
            String::new()
        };

        // -- route --
        let mut out = match (method.as_str(), path.as_str()) {
            ("GET", "/v1/status") => {
                resp.clear();
                shared.status_json().write_compact_into(&mut resp);
                HttpOutcome::ok()
            }
            ("POST", "/v1/evaluate") => http_forward(shared, &body, "evaluate", &mut resp),
            ("POST", "/v1/energy") => http_forward(shared, &body, "energy", &mut resp),
            ("POST", "/v1/select") => http_forward(shared, &body, "select", &mut resp),
            ("POST", "/v1/reconfigure") => http_reconfigure(shared, &body, &mut resp),
            ("GET" | "POST", _) => {
                let detail = format!("no route for {method} {path}");
                error_body_into(&mut resp, -1, "not_found", "unknown route", &detail);
                HttpOutcome::err(404, "Not Found")
            }
            _ => {
                error_body_into(&mut resp, -1, "method_not_allowed", "use GET or POST", &method);
                HttpOutcome::err(405, "Method Not Allowed")
            }
        };
        out.close = out.close || connection_close;
        let write_ok = write_response(&mut writer, &out, &resp).is_ok();
        if !write_ok || out.close {
            return;
        }
    }
}

/// Decode one HTTP body, forward it over the ring as a canonical NDJSON
/// line, and translate the response envelope to an HTTP outcome.
fn http_forward(
    shared: &Arc<RouterShared>,
    body: &str,
    route_op: &str,
    resp: &mut String,
) -> HttpOutcome {
    let req = match wire::decode_body(body, route_op) {
        Ok(req) => req,
        Err(e) => {
            shared.stats.errors.fetch_add(1, Ordering::Relaxed);
            error_body_into(resp, -1, "bad_request", "request body could not be decoded", &format!("{e:#}"));
            return HttpOutcome::err(400, "Bad Request");
        }
    };
    let line = request_line(&req);
    let answer = shared.forward(route_key(&req), req.id, &line, hedgeable(&req.op));
    envelope_outcome(&answer, req.id, resp)
}

/// `POST /v1/reconfigure` on the router: decoded like any POST body, but
/// broadcast to the whole live fleet instead of routed to one shard.
fn http_reconfigure(shared: &Arc<RouterShared>, body: &str, resp: &mut String) -> HttpOutcome {
    let req = match wire::decode_body(body, "reconfigure") {
        Ok(req) => req,
        Err(e) => {
            shared.stats.errors.fetch_add(1, Ordering::Relaxed);
            error_body_into(resp, -1, "bad_request", "request body could not be decoded", &format!("{e:#}"));
            return HttpOutcome::err(400, "Bad Request");
        }
    };
    let line = request_line(&req);
    let answer = shared.broadcast_reconfigure(req.id, &line);
    envelope_outcome(&answer, req.id, resp)
}

/// Map an NDJSON response envelope onto an HTTP outcome (200 / 503 shed +
/// `Retry-After` / 404 unknown model / 400); the body is the envelope.
fn envelope_outcome(answer: &str, id: i64, resp: &mut String) -> HttpOutcome {
    resp.clear();
    resp.push_str(answer);
    let Ok(j) = Json::parse(answer) else {
        error_body_into(resp, id, "internal", "shard response was not valid JSON", "");
        return HttpOutcome::err(500, "Internal Server Error");
    };
    if j.get("ok").and_then(|v| v.as_bool()).unwrap_or(false) {
        return HttpOutcome::ok();
    }
    if j.get("shed").and_then(|v| v.as_bool()).unwrap_or(false) {
        return HttpOutcome { status: 503, reason: "Service Unavailable", retry_after: true, close: false };
    }
    let err = j.get("error").ok().and_then(|v| v.as_str().ok()).unwrap_or("");
    if err.starts_with("unknown model") {
        HttpOutcome::err(404, "Not Found")
    } else {
        HttpOutcome::err(400, "Bad Request")
    }
}

/// Re-encode a decoded request as a canonical NDJSON line (the HTTP front
/// door's bridge onto the line protocol). Non-finite Ω entries cross as
/// `null`, which the shard's decoder reads back as NaN — the same image
/// the tree codec uses — so `decode_line(request_line(r)) == r`.
fn request_line(req: &Request) -> String {
    let mut j = Json::obj().with("id", req.id);
    if let Some(m) = &req.model {
        j = j.with("model", m.as_str());
    }
    j = match &req.op {
        Op::Evaluate { batches, selection } => {
            let mut j = j.with("op", "evaluate").with("batches", *batches);
            if let Some(s) = selection {
                j = j.with("selection", s.as_slice());
            }
            j
        }
        Op::Energy { selection } => j.with("op", "energy").with("selection", selection.as_slice()),
        Op::Select { r_energy, omega } => {
            let rows: Vec<Json> = omega
                .iter()
                .map(|row| Json::Arr(row.iter().map(|&v| Json::from(v)).collect()))
                .collect();
            j.with("op", "select").with("r_energy", *r_energy).with("omega", Json::Arr(rows))
        }
        Op::ArtifactGet { kind, fingerprint } => j
            .with("op", "artifact_get")
            .with("kind", kind.as_str())
            .with("fingerprint", fingerprint.as_str()),
        Op::ArtifactPut { kind, envelope } => {
            j.with("op", "artifact_put").with("kind", kind.as_str()).with("envelope", envelope.clone())
        }
        Op::Reconfigure { delta } => j.with("op", "reconfigure").with("delta", delta.clone()),
        Op::Health => j.with("op", "health"),
        Op::Status => j.with("op", "status"),
        Op::Shutdown => j.with("op", "shutdown"),
    };
    j.compact()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_line_round_trips_through_the_decoder() {
        let cases = vec![
            Request { id: 7, model: Some("m/c".into()), op: Op::Evaluate { batches: 3, selection: None } },
            Request {
                id: 1,
                model: None,
                op: Op::Evaluate { batches: 1, selection: Some(vec![0, 2, 1]) },
            },
            Request { id: 2, model: Some("a/b".into()), op: Op::Energy { selection: vec![1, 1] } },
            Request {
                id: 3,
                model: None,
                op: Op::Select { r_energy: 0.7, omega: vec![vec![0.1, f64::NAN], vec![0.2]] },
            },
            Request {
                id: 4,
                model: None,
                op: Op::ArtifactGet { kind: "library".into(), fingerprint: "00deadbeef00cafe".into() },
            },
            Request {
                id: 5,
                model: None,
                op: Op::ArtifactPut {
                    kind: "k".into(),
                    envelope: Json::obj().with("schema", "fames-store-v1").with("payload", 1i64),
                },
            },
            Request {
                id: 6,
                model: Some("m/c".into()),
                op: Op::Reconfigure {
                    delta: Json::obj().with("calib_epochs", 2i64).with("r_energy", 0.6),
                },
            },
        ];
        for req in cases {
            let line = request_line(&req);
            let back = wire::decode_line(&line).expect(&line);
            // NaN-bearing requests compare via Debug (NaN != NaN).
            assert_eq!(format!("{req:?}"), format!("{back:?}"), "round trip of {line}");
        }
    }

    #[test]
    fn shard_response_classifiers() {
        let conn_shed = wire::shed_line(-1, admission::OVERLOADED_CONNS);
        assert!(is_conn_refusal(&conn_shed));
        assert!(!reusable(&conn_shed));

        let req_shed = wire::shed_line(9, admission::OVERLOADED_QUEUE);
        assert!(!is_conn_refusal(&req_shed));
        assert!(reusable(&req_shed));

        let ok = wire::ok_line(3, &Json::obj().with("accuracy", 0.5));
        assert!(!is_conn_refusal(&ok));
        assert!(reusable(&ok));
        assert!(unknown_model_error(&ok).is_none());

        let unknown = wire::err_line(4, "unknown model 'x/y' (loaded: a/b)");
        assert!(unknown_model_error(&unknown).is_some());
        let other_err = wire::err_line(4, "selection has 3 picks, model has 2 layers");
        assert!(unknown_model_error(&other_err).is_none());
    }

    #[test]
    fn pool_cooldown_fails_fast() {
        // Nothing listens on this address; the first round trip marks the
        // shard down, the second fails fast on the cooldown.
        let p = Pool::new(
            "127.0.0.1:1".to_string(),
            2,
            Duration::from_millis(50),
            Duration::from_millis(100),
            Duration::from_millis(500),
        );
        assert!(p.round_trip("{\"id\":1,\"op\":\"status\"}").is_err());
        assert!(p.is_down());
        let err = p.round_trip("{\"id\":1,\"op\":\"status\"}").unwrap_err();
        assert!(format!("{err:#}").contains("cooling down"), "{err:#}");
    }

    #[test]
    fn router_sheds_when_all_shards_are_down() {
        let cfg = RouterConfig {
            addr: "127.0.0.1:0".to_string(),
            shards: vec!["127.0.0.1:1".to_string()],
            connect_timeout_ms: 50,
            io_timeout_ms: 100,
            ..RouterConfig::default()
        };
        let r = Router::bind(&cfg).unwrap();
        let line = r.shared.forward("m/c", 42, "{\"id\":42,\"op\":\"status\"}", false);
        assert!(line.contains("\"shed\":true"), "{line}");
        assert!(line.contains("\"id\":42"), "{line}");
        assert_eq!(r.shared.stats.shed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn draining_classifier_matches_only_the_drain_shed() {
        assert!(is_draining(&wire::shed_line(7, admission::DRAINING)));
        assert!(!is_draining(&wire::shed_line(7, admission::OVERLOADED_QUEUE)));
        assert!(!is_draining(&wire::err_line(7, admission::DRAINING)), "non-shed error relays");
        assert!(!is_draining(&wire::ok_line(7, &Json::obj().with("x", 1i64))));
    }

    #[test]
    fn membership_ejects_down_shards_from_routing() {
        let cfg = RouterConfig {
            addr: "127.0.0.1:0".to_string(),
            shards: vec!["127.0.0.1:1".to_string(), "127.0.0.1:2".to_string()],
            connect_timeout_ms: 50,
            io_timeout_ms: 100,
            ..RouterConfig::default()
        };
        let r = Router::bind(&cfg).unwrap();
        // Mark every shard Down via missed probes: forward must shed
        // immediately, without dialing anything (no cooldown needed).
        for shard in 0..2 {
            for _ in 0..health::MISSES_TO_DOWN {
                r.shared.membership.probe_missed(shard);
            }
        }
        let t0 = Instant::now();
        let line = r.shared.forward("m/c", 9, "{\"id\":9,\"op\":\"status\"}", false);
        assert!(line.contains(ALL_SHARDS_DOWN), "{line}");
        assert!(
            t0.elapsed() < Duration::from_millis(50),
            "ejected shards must not be dialed (took {:?})",
            t0.elapsed()
        );
    }

    #[test]
    fn should_hedge_needs_samples_a_fleet_and_a_slow_owner() {
        let cfg = RouterConfig {
            addr: "127.0.0.1:0".to_string(),
            shards: vec!["127.0.0.1:1".to_string(), "127.0.0.1:2".to_string()],
            hedge_threshold: 3.0,
            ..RouterConfig::default()
        };
        let r = Router::bind(&cfg).unwrap();
        assert!(!r.shared.should_hedge(0), "empty windows never hedge");
        for _ in 0..HEDGE_MIN_SAMPLES {
            r.shared.pools[0].window.record(100.0);
            r.shared.pools[1].window.record(1.0);
        }
        assert!(r.shared.should_hedge(0), "owner p99 100ms vs median 1ms");
        assert!(!r.shared.should_hedge(1), "the fast shard is not hedged");
        // Disabled threshold switches it all off.
        let cfg = RouterConfig { hedge_threshold: 0.0, ..cfg };
        let r2 = Router::bind(&cfg).unwrap();
        for _ in 0..HEDGE_MIN_SAMPLES {
            r2.shared.pools[0].window.record(100.0);
            r2.shared.pools[1].window.record(1.0);
        }
        assert!(!r2.shared.should_hedge(0));
    }
}
