//! Deterministic, seed-driven fault injection for the serve wire layer.
//!
//! A [`FaultPlan`] is a compact schedule of injected failures — response
//! drops, delays and truncations, connection refusals, and a
//! kill-after-N-requests switch — every decision hashed (FNV, no `rand`)
//! from the plan seed and a monotone event counter. Two servers given the
//! same spec replay the *exact same* failure schedule, which is what lets
//! the chaos suite assert byte-level outcomes instead of probabilities.
//!
//! Production binaries never inject faults unless the operator opts in
//! via the [`FAULT_ENV`] environment variable (`FAMES_FAULT=spec`); tests
//! and benches attach a plan directly on [`crate::serve::ServeConfig`].
//!
//! Spec grammar: `;`- or `,`-separated `key=value` pairs, e.g.
//! `seed=42;delay_ms=100;delay_every=1;kill_after=200`. Keys:
//!
//! | key             | meaning                                              |
//! |-----------------|------------------------------------------------------|
//! | `seed`          | schedule seed (default 0)                            |
//! | `delay_every`   | delay ~1/N response lines by `delay_ms` (0 = never)  |
//! | `delay_ms`      | injected response delay in ms (default 100)          |
//! | `drop_every`    | silently drop ~1/N response lines (0 = never)        |
//! | `truncate_every`| cut ~1/N response lines mid-byte + kill the conn     |
//! | `refuse_every`  | close ~1/N accepted connections without a byte       |
//! | `kill_after`    | begin shutdown after N decoded requests (0 = never)  |

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::util::hash::Fnv64;

/// Environment variable a production daemon reads its fault spec from.
pub const FAULT_ENV: &str = "FAMES_FAULT";

/// What the writer should do with the next response line.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResponseAction {
    /// No fault scheduled for this line.
    Deliver,
    /// Sleep before delivering (tail-latency injection).
    Delay(Duration),
    /// Never send the line; the connection stays open (the peer times out).
    Drop,
    /// Send only a prefix of the line, no newline, then kill the connection.
    Truncate,
}

/// A deterministic failure schedule (see module docs for the spec grammar).
///
/// The per-event counters live in the plan, so one plan drives one server:
/// event `n`'s verdict is `FNV(seed, domain, n) % every == 0`, replayable
/// run-to-run and independent of thread interleaving *given the same
/// per-event ordinals*.
#[derive(Debug, Default)]
pub struct FaultPlan {
    seed: u64,
    delay_ms: u64,
    delay_every: u64,
    drop_every: u64,
    truncate_every: u64,
    refuse_every: u64,
    kill_after: u64,
    responses: AtomicU64,
    conns: AtomicU64,
    requests: AtomicU64,
}

impl FaultPlan {
    /// Parse a spec string (see module docs). Unknown keys are rejected so
    /// a typo can't silently disable the schedule.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan { delay_ms: 100, ..FaultPlan::default() };
        for part in spec.split([';', ',']).map(str::trim).filter(|p| !p.is_empty()) {
            let (key, value) = part
                .split_once('=')
                .with_context(|| format!("fault spec `{part}`: expected key=value"))?;
            let v: u64 = value
                .trim()
                .parse()
                .with_context(|| format!("fault spec `{part}`: value is not an integer"))?;
            match key.trim() {
                "seed" => plan.seed = v,
                "delay_ms" => plan.delay_ms = v,
                "delay_every" => plan.delay_every = v,
                "drop_every" => plan.drop_every = v,
                "truncate_every" => plan.truncate_every = v,
                "refuse_every" => plan.refuse_every = v,
                "kill_after" => plan.kill_after = v,
                other => bail!(
                    "fault spec: unknown key `{other}` \
                     (seed|delay_ms|delay_every|drop_every|truncate_every|refuse_every|kill_after)"
                ),
            }
        }
        Ok(plan)
    }

    /// The opt-in production path: `Some(plan)` iff [`FAULT_ENV`] is set.
    pub fn from_env() -> Result<Option<FaultPlan>> {
        match std::env::var(FAULT_ENV) {
            Ok(spec) if !spec.trim().is_empty() => {
                Ok(Some(Self::parse(&spec).with_context(|| format!("parsing ${FAULT_ENV}"))?))
            }
            _ => Ok(None),
        }
    }

    /// Does event `n` of `domain` fire under a 1-in-`every` schedule?
    fn fires(&self, domain: &str, n: u64, every: u64) -> bool {
        match every {
            0 => false,
            1 => true,
            _ => {
                let mut h = Fnv64::new();
                h.write_str("fames-fault");
                h.write_u64(self.seed);
                h.write_str(domain);
                h.write_u64(n);
                h.finish() % every == 0
            }
        }
    }

    /// Verdict for the next response line (drop > truncate > delay).
    pub fn response_action(&self) -> ResponseAction {
        let n = self.responses.fetch_add(1, Ordering::Relaxed);
        if self.fires("drop", n, self.drop_every) {
            ResponseAction::Drop
        } else if self.fires("truncate", n, self.truncate_every) {
            ResponseAction::Truncate
        } else if self.fires("delay", n, self.delay_every) {
            ResponseAction::Delay(Duration::from_millis(self.delay_ms))
        } else {
            ResponseAction::Deliver
        }
    }

    /// Should the next accepted connection be closed without a byte?
    pub fn refuse_conn(&self) -> bool {
        let n = self.conns.fetch_add(1, Ordering::Relaxed);
        self.fires("refuse", n, self.refuse_every)
    }

    /// Count one decoded request; `true` exactly once, on request number
    /// `kill_after` — the caller begins a clean shutdown.
    pub fn note_request(&self) -> bool {
        if self.kill_after == 0 {
            return false;
        }
        self.requests.fetch_add(1, Ordering::Relaxed) + 1 == self.kill_after
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_spec_and_rejects_typos() {
        let p = FaultPlan::parse(
            "seed=42; delay_ms=100, delay_every=3;drop_every=5;truncate_every=7;\
             refuse_every=9;kill_after=200",
        )
        .unwrap();
        assert_eq!(p.seed, 42);
        assert_eq!(p.delay_ms, 100);
        assert_eq!(p.delay_every, 3);
        assert_eq!(p.kill_after, 200);
        assert!(FaultPlan::parse("dropevery=5").is_err(), "unknown key must be rejected");
        assert!(FaultPlan::parse("drop_every=x").is_err(), "non-integer must be rejected");
        assert!(FaultPlan::parse("drop_every").is_err(), "bare key must be rejected");
        // Empty spec is a valid no-op plan.
        let noop = FaultPlan::parse("").unwrap();
        assert_eq!(noop.response_action(), ResponseAction::Deliver);
        assert!(!noop.refuse_conn());
        assert!(!noop.note_request());
    }

    #[test]
    fn same_seed_replays_the_same_schedule() {
        let spec = "seed=7;delay_every=3;drop_every=5;truncate_every=11;refuse_every=4";
        let a = FaultPlan::parse(spec).unwrap();
        let b = FaultPlan::parse(spec).unwrap();
        let run = |p: &FaultPlan| -> (Vec<ResponseAction>, Vec<bool>) {
            ((0..200).map(|_| p.response_action()).collect(), (0..50).map(|_| p.refuse_conn()).collect())
        };
        assert_eq!(run(&a), run(&b), "same spec must replay the same schedule");
        // A different seed produces a different schedule (with these odds,
        // 200 events colliding would mean the hash is ignoring the seed).
        let c = FaultPlan::parse("seed=8;delay_every=3;drop_every=5;truncate_every=11").unwrap();
        assert_ne!(run(&a).0, run(&c).0);
        // The schedule actually fires: roughly 1/3 + 1/5 + 1/11 of events.
        let fired = run(&FaultPlan::parse(spec).unwrap())
            .0
            .iter()
            .filter(|a| **a != ResponseAction::Deliver)
            .count();
        assert!(fired > 20, "schedule fired only {fired}/200 events");
    }

    #[test]
    fn kill_after_fires_exactly_once_at_n() {
        let p = FaultPlan::parse("kill_after=5").unwrap();
        let verdicts: Vec<bool> = (0..10).map(|_| p.note_request()).collect();
        assert_eq!(verdicts, vec![false, false, false, false, true, false, false, false, false, false]);
    }

    #[test]
    fn every_one_fires_always() {
        let p = FaultPlan::parse("refuse_every=1").unwrap();
        assert!((0..10).all(|_| p.refuse_conn()));
        let p = FaultPlan::parse("delay_every=1;delay_ms=17").unwrap();
        assert!((0..10)
            .all(|_| p.response_action() == ResponseAction::Delay(Duration::from_millis(17))));
    }
}
