//! Consistent-hash ring for the sharded serve fleet.
//!
//! Each shard (identified by its `host:port` address) owns a fixed number
//! of virtual nodes; the ring is the sorted list of their hash points. A
//! routing key — the wire request's `<model>/<cfg>` — maps to the first
//! point clockwise from its own hash, so every router instance built from
//! the same shard list computes the same assignment with no coordination.
//!
//! Virtual nodes keep the load split even when shard counts are small
//! (with one point per shard, a 2-shard ring can be arbitrarily lopsided),
//! and they bound reshuffling: adding or removing one shard only moves the
//! keys that hashed into its arcs, roughly `1/N` of the keyspace.
//!
//! Hashing is [`crate::util::hash::Fnv64`] with length-prefixed writes, so
//! point positions are a stable part of the wire contract: a key routes to
//! the same shard across processes, restarts, and releases.

use crate::util::hash::Fnv64;

/// Virtual nodes per shard. Fixed (not configurable) so that every router
/// and test in the fleet agrees on the ring geometry.
pub const VNODES: usize = 64;

/// An immutable consistent-hash ring over shard addresses.
#[derive(Clone, Debug)]
pub struct Ring {
    /// Shard addresses in the order given at construction; `route` returns
    /// indices into this list.
    shards: Vec<String>,
    /// Sorted `(point, shard index)` pairs, `VNODES` per shard.
    points: Vec<(u64, usize)>,
}

fn point_hash(addr: &str, vnode: usize) -> u64 {
    let mut h = Fnv64::new();
    h.write_str("fames-ring-shard");
    h.write_str(addr);
    h.write_u64(vnode as u64);
    h.finish()
}

fn key_hash(key: &str) -> u64 {
    let mut h = Fnv64::new();
    h.write_str("fames-ring-key");
    h.write_str(key);
    h.finish()
}

impl Ring {
    /// Build a ring over the given shard addresses. Order is preserved for
    /// index reporting but does not affect key placement (points depend
    /// only on the address strings).
    pub fn new<S: Into<String>>(shards: impl IntoIterator<Item = S>) -> Ring {
        let shards: Vec<String> = shards.into_iter().map(Into::into).collect();
        let mut points = Vec::with_capacity(shards.len() * VNODES);
        for (i, addr) in shards.iter().enumerate() {
            for v in 0..VNODES {
                points.push((point_hash(addr, v), i));
            }
        }
        // Ties broken by shard index so duplicate addresses still yield a
        // deterministic ring.
        points.sort_unstable();
        Ring { shards, points }
    }

    pub fn shards(&self) -> &[String] {
        &self.shards
    }

    pub fn len(&self) -> usize {
        self.shards.len()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    /// Index (into `shards`) of the first ring point at or clockwise from
    /// the key's hash. Panics on an empty ring.
    pub fn route(&self, key: &str) -> usize {
        assert!(!self.points.is_empty(), "route on an empty ring");
        let h = key_hash(key);
        let i = self.points.partition_point(|&(p, _)| p < h);
        self.points[if i == self.points.len() { 0 } else { i }].1
    }

    /// Shard address a key routes to.
    pub fn route_addr(&self, key: &str) -> &str {
        &self.shards[self.route(key)]
    }

    /// All distinct shards in ring order starting from the key's primary —
    /// the failover sequence. Every shard appears exactly once, so walking
    /// the list tries the whole fleet.
    pub fn successors(&self, key: &str) -> Vec<usize> {
        if self.points.is_empty() {
            return Vec::new();
        }
        let h = key_hash(key);
        let start = self.points.partition_point(|&(p, _)| p < h);
        let mut seen = vec![false; self.shards.len()];
        let mut order = Vec::with_capacity(self.shards.len());
        for k in 0..self.points.len() {
            let (_, shard) = self.points[(start + k) % self.points.len()];
            if !seen[shard] {
                seen[shard] = true;
                order.push(shard);
                if order.len() == self.shards.len() {
                    break;
                }
            }
        }
        order
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addrs(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("127.0.0.1:{}", 9100 + i)).collect()
    }

    #[test]
    fn routing_is_deterministic_and_order_independent() {
        let a = Ring::new(addrs(4));
        let mut rev = addrs(4);
        rev.reverse();
        let b = Ring::new(rev);
        for i in 0..200 {
            let key = format!("model{i}/w4a4");
            assert_eq!(a.route_addr(&key), b.route_addr(&key), "key {key}");
        }
    }

    #[test]
    fn single_shard_takes_everything() {
        let r = Ring::new(["127.0.0.1:9100"]);
        for i in 0..50 {
            assert_eq!(r.route(&format!("k{i}")), 0);
        }
    }

    #[test]
    fn load_is_roughly_balanced() {
        let r = Ring::new(addrs(4));
        let mut counts = [0usize; 4];
        let n = 4000;
        for i in 0..n {
            counts[r.route(&format!("model{i}/cfg{}", i % 7))] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            // Fair share is 1000; virtual nodes should keep every shard
            // within a loose 2x band.
            assert!(c > n / 8 && c < n / 2, "shard {i} got {c} of {n}");
        }
    }

    #[test]
    fn removing_a_shard_only_moves_its_keys() {
        let full = Ring::new(addrs(4));
        let reduced = Ring::new(addrs(3)); // drops shard index 3
        let mut moved = 0;
        let n = 2000;
        for i in 0..n {
            let key = format!("m{i}/c");
            let before = full.route_addr(&key).to_string();
            let after = reduced.route_addr(&key).to_string();
            if before != after {
                // Only keys that lived on the removed shard may move.
                assert_eq!(before, full.shards()[3], "key {key} moved off a surviving shard");
                moved += 1;
            }
        }
        // Roughly 1/4 of keys should move, never the majority.
        assert!(moved > n / 10 && moved < n / 2, "moved {moved} of {n}");
    }

    #[test]
    fn successors_cover_all_shards_once() {
        let r = Ring::new(addrs(4));
        for i in 0..50 {
            let key = format!("m{i}/c");
            let order = r.successors(&key);
            assert_eq!(order.len(), 4);
            assert_eq!(order[0], r.route(&key));
            let mut sorted = order.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, vec![0, 1, 2, 3]);
        }
    }

    #[test]
    fn empty_ring_has_no_successors() {
        let r = Ring::new(Vec::<String>::new());
        assert!(r.is_empty());
        assert!(r.successors("k").is_empty());
    }

    /// Property test: routing under membership churn. For every subset of
    /// live members (all 2^n liveness assignments of a 5-shard ring,
    /// i.e. every reachable [`View`]) and a sweep of keys:
    ///
    /// * the filtered order never contains a `Down` shard;
    /// * the filtered order is exactly the ring successor order with the
    ///   `Down` shards deleted (churn never *reorders* the failover walk);
    /// * routing is a pure function of `(view, key)` — recomputing with an
    ///   equal view yields an identical order, and a view generation bump
    ///   with identical states changes nothing but the generation.
    #[test]
    fn filtered_routing_is_pure_and_never_hits_down_members() {
        use crate::serve::health::{Liveness, View};

        let n = 5;
        let ring = Ring::new(addrs(n));
        for mask in 0u32..(1 << n) {
            let states: Vec<Liveness> = (0..n)
                .map(|i| {
                    if mask & (1 << i) != 0 {
                        Liveness::Up
                    } else {
                        Liveness::Down
                    }
                })
                .collect();
            let view = View::from_states(states.clone(), mask as u64);
            for k in 0..40 {
                let key = format!("model{k}/w{}a{}", k % 9, k % 5);
                let full = ring.successors(&key);
                let live = view.filter_order(&full);

                // No Down member is ever routed to.
                for &s in &live {
                    assert_ne!(view.liveness(s), Liveness::Down, "mask {mask:b} key {key}");
                }
                // Exactly the live members, in unchanged ring order.
                let expect: Vec<usize> = full
                    .iter()
                    .copied()
                    .filter(|&s| mask & (1 << s) != 0)
                    .collect();
                assert_eq!(live, expect, "mask {mask:b} key {key}");
                assert_eq!(live.len() as u32, mask.count_ones());

                // Purity: same view ⇒ same order; a generation bump with
                // the same states changes nothing about routing.
                let again = View::from_states(states.clone(), mask as u64);
                assert_eq!(again.filter_order(&full), live);
                let bumped = View::from_states(states.clone(), mask as u64 + 1000);
                assert_eq!(bumped.filter_order(&full), live);
            }
        }
    }
}
