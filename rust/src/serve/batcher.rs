//! Request batcher — coalesces concurrent requests into `util::par` waves.
//!
//! Connection readers enqueue parsed compute requests ([`Job`]s) into one
//! shared FIFO; a single dispatcher thread drains up to `max_batch` jobs at
//! a time and scores the whole wave through `util::par::par_map`, so N
//! concurrent clients turn into one fused batched invocation of the kernel
//! layer per wave (each worker drives the native backend's fused
//! LUT/GEMM kernels, checking buffers out of the per-executable
//! `kernel::Scratch` pool). Per-request results are exactly the direct
//! `Session` call — batching changes *when* a request runs, never *what*
//! it computes — which is the serving layer's bit-identity guarantee.
//!
//! Shutdown drains: `close()` wakes the dispatcher, but `next_wave` keeps
//! handing out queued jobs until the FIFO is empty, so every accepted
//! request is answered before the serve loop exits.

use std::collections::VecDeque;
use std::sync::mpsc::Sender;
use std::sync::{Condvar, Mutex};

use super::codec::Request;

/// One queued compute request plus its connection's outbound line channel.
pub struct Job {
    pub request: Request,
    pub reply: Sender<String>,
}

struct QueueState {
    jobs: VecDeque<Job>,
    closed: bool,
}

/// Shared FIFO + condvar (no external deps; `std` primitives only).
pub struct Batcher {
    queue: Mutex<QueueState>,
    cv: Condvar,
    /// Most jobs one wave may carry (CLI `max_batch=`).
    pub max_batch: usize,
}

impl Batcher {
    pub fn new(max_batch: usize) -> Batcher {
        Batcher {
            queue: Mutex::new(QueueState { jobs: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            max_batch: max_batch.max(1),
        }
    }

    /// Enqueue a job; `false` when the batcher is already closed (the
    /// caller should answer with a shutting-down error instead).
    pub fn enqueue(&self, job: Job) -> bool {
        let mut q = self.queue.lock().unwrap();
        if q.closed {
            return false;
        }
        q.jobs.push_back(job);
        self.cv.notify_all();
        true
    }

    /// Block until at least one job is queued (or the batcher closes with
    /// an empty queue — then `None`). Drains up to `max_batch` jobs.
    pub fn next_wave(&self) -> Option<Vec<Job>> {
        let mut q = self.queue.lock().unwrap();
        loop {
            if !q.jobs.is_empty() {
                let n = q.jobs.len().min(self.max_batch);
                return Some(q.jobs.drain(..n).collect());
            }
            if q.closed {
                return None;
            }
            q = self.cv.wait(q).unwrap();
        }
    }

    /// Stop accepting; queued jobs still drain through `next_wave`.
    pub fn close(&self) {
        self.queue.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Jobs currently queued (the `status` response's queue depth).
    pub fn pending(&self) -> usize {
        self.queue.lock().unwrap().jobs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::super::codec::{parse_request, Request};
    use super::*;
    use std::sync::mpsc;
    use std::sync::Arc;

    fn job(id: i64) -> (Job, mpsc::Receiver<String>) {
        let (tx, rx) = mpsc::channel();
        let request: Request =
            parse_request(&format!(r#"{{"id":{id},"op":"status"}}"#)).unwrap();
        (Job { request, reply: tx }, rx)
    }

    #[test]
    fn waves_respect_fifo_order_and_max_batch() {
        let b = Batcher::new(2);
        let mut rxs = Vec::new();
        for id in 0..5 {
            let (j, rx) = job(id);
            assert!(b.enqueue(j));
            rxs.push(rx);
        }
        assert_eq!(b.pending(), 5);
        let ids = |wave: &[Job]| wave.iter().map(|j| j.request.id).collect::<Vec<_>>();
        assert_eq!(ids(&b.next_wave().unwrap()), vec![0, 1]);
        assert_eq!(ids(&b.next_wave().unwrap()), vec![2, 3]);
        assert_eq!(ids(&b.next_wave().unwrap()), vec![4]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn close_drains_queued_jobs_then_ends() {
        let b = Batcher::new(8);
        let (j, _rx) = job(1);
        assert!(b.enqueue(j));
        b.close();
        let (j2, _rx2) = job(2);
        assert!(!b.enqueue(j2), "closed batcher must reject new jobs");
        assert_eq!(b.next_wave().unwrap().len(), 1, "queued job drains after close");
        assert!(b.next_wave().is_none(), "empty + closed ends the dispatcher");
    }

    #[test]
    fn next_wave_blocks_until_work_arrives() {
        let b = Arc::new(Batcher::new(4));
        let b2 = b.clone();
        let waiter = std::thread::spawn(move || b2.next_wave().map(|w| w.len()));
        std::thread::sleep(std::time::Duration::from_millis(30));
        let (j, _rx) = job(7);
        assert!(b.enqueue(j));
        assert_eq!(waiter.join().unwrap(), Some(1));
    }
}
