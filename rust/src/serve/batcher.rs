//! Request batcher — coalesces concurrent requests into `util::par` waves,
//! with admission control and per-client fairness.
//!
//! Connection readers enqueue parsed compute requests ([`Job`]s); a single
//! dispatcher thread drains up to `max_batch` jobs at a time and scores the
//! whole wave through `util::par::par_map`, so N concurrent clients turn
//! into one fused batched invocation of the kernel layer per wave. Per-
//! request results are exactly the direct `Session` call — batching changes
//! *when* a request runs, never *what* it computes — which is the serving
//! layer's bit-identity guarantee.
//!
//! # Bounded queue (load shedding)
//!
//! The queue holds at most `max_pending` jobs across all clients. Past
//! that, [`Batcher::enqueue`] returns [`Enqueue::Shed`] and the caller
//! answers with an explicit retry-able shed response instead of queueing
//! unbounded work — the backpressure half of `serve::admission`.
//!
//! # Round-robin fairness
//!
//! Jobs are queued **per client** and waves are filled by cycling over
//! client queues (one job per client per rotation, resuming after the last
//! served client). A connection pipelining hundreds of requests therefore
//! cannot starve another client: the second client's first request joins
//! the very next wave rather than queueing behind the flood. With a single
//! client the rotation degenerates to the old FIFO order, so response
//! bytes and ordering are unchanged for the existing tests.
//!
//! Shutdown drains: `close()` wakes the dispatcher, but `next_wave` keeps
//! handing out queued jobs until every queue is empty, so every accepted
//! request is answered before the serve loop exits.

use std::collections::{BTreeMap, VecDeque};
use std::ops::Bound;
use std::sync::{Condvar, Mutex};

use super::codec::Request;
use super::ReplySink;

/// One queued compute request, its originating client (fairness key) and
/// the sink its response goes back through.
pub struct Job {
    /// Connection id assigned at accept time — the round-robin key.
    pub client: u64,
    pub request: Request,
    pub sink: ReplySink,
}

/// Outcome of [`Batcher::enqueue`].
#[derive(Debug, PartialEq, Eq)]
pub enum Enqueue {
    /// Queued; the dispatcher will answer through the job's sink.
    Ok,
    /// Queue full — the caller must send an explicit shed response.
    Shed,
    /// Batcher closed (shutdown in progress) — answer shutting-down.
    Closed,
}

struct QueueState {
    /// Per-client FIFO queues, keyed by connection id.
    queues: BTreeMap<u64, VecDeque<Job>>,
    /// Total queued jobs across all clients (the `max_pending` gauge).
    pending: usize,
    /// Round-robin cursor: the next wave slot goes to the first client id
    /// strictly greater than this (wrapping to the smallest).
    cursor: u64,
    closed: bool,
}

/// Shared queues + condvar (no external deps; `std` primitives only).
pub struct Batcher {
    queue: Mutex<QueueState>,
    cv: Condvar,
    /// Most jobs one wave may carry (CLI `max_batch=`).
    pub max_batch: usize,
    /// Most jobs queued across all clients (CLI `max_pending=`).
    pub max_pending: usize,
}

impl Batcher {
    pub fn new(max_batch: usize, max_pending: usize) -> Batcher {
        Batcher {
            queue: Mutex::new(QueueState {
                queues: BTreeMap::new(),
                pending: 0,
                cursor: 0,
                closed: false,
            }),
            cv: Condvar::new(),
            max_batch: max_batch.max(1),
            max_pending: max_pending.max(1),
        }
    }

    /// Enqueue a job on its client's queue, shedding past `max_pending`.
    pub fn enqueue(&self, job: Job) -> Enqueue {
        let mut q = self.queue.lock().unwrap();
        if q.closed {
            return Enqueue::Closed;
        }
        if q.pending >= self.max_pending {
            return Enqueue::Shed;
        }
        q.queues.entry(job.client).or_default().push_back(job);
        q.pending += 1;
        self.cv.notify_all();
        Enqueue::Ok
    }

    /// Block until at least one job is queued (or the batcher closes with
    /// empty queues — then `None`). Fills a wave of up to `max_batch` jobs
    /// round-robin across clients.
    pub fn next_wave(&self) -> Option<Vec<Job>> {
        let mut q = self.queue.lock().unwrap();
        loop {
            if q.pending > 0 {
                return Some(Self::drain_wave(&mut q, self.max_batch));
            }
            if q.closed {
                return None;
            }
            q = self.cv.wait(q).unwrap();
        }
    }

    /// One job per client per rotation, resuming after `cursor`, cycling
    /// until the wave is full or the queues are empty.
    fn drain_wave(q: &mut QueueState, max_batch: usize) -> Vec<Job> {
        let mut wave = Vec::with_capacity(max_batch.min(q.pending));
        while wave.len() < max_batch && q.pending > 0 {
            let key = q
                .queues
                .range((Bound::Excluded(q.cursor), Bound::Unbounded))
                .next()
                .map(|(k, _)| *k)
                .or_else(|| q.queues.keys().next().copied());
            let Some(key) = key else { break };
            q.cursor = key;
            let mut emptied = false;
            if let Some(jobs) = q.queues.get_mut(&key) {
                if let Some(job) = jobs.pop_front() {
                    wave.push(job);
                    q.pending -= 1;
                }
                emptied = jobs.is_empty();
            }
            if emptied {
                q.queues.remove(&key);
            }
        }
        wave
    }

    /// Stop accepting; queued jobs still drain through `next_wave`.
    pub fn close(&self) {
        self.queue.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Jobs currently queued (the `status` response's queue depth).
    pub fn pending(&self) -> usize {
        self.queue.lock().unwrap().pending
    }
}

#[cfg(test)]
mod tests {
    use super::super::codec::{parse_request, Request};
    use super::super::ReplySink;
    use super::*;
    use std::sync::mpsc;
    use std::sync::Arc;

    fn job(client: u64, id: i64) -> (Job, mpsc::Receiver<String>) {
        let (tx, rx) = mpsc::sync_channel(64);
        let request: Request = parse_request(&format!(r#"{{"id":{id},"op":"status"}}"#)).unwrap();
        (Job { client, request, sink: ReplySink::Line { tx, conn: None } }, rx)
    }

    #[test]
    fn single_client_waves_keep_fifo_order_and_max_batch() {
        let b = Batcher::new(2, 1024);
        let mut rxs = Vec::new();
        for id in 0..5 {
            let (j, rx) = job(1, id);
            assert_eq!(b.enqueue(j), Enqueue::Ok);
            rxs.push(rx);
        }
        assert_eq!(b.pending(), 5);
        let ids = |wave: &[Job]| wave.iter().map(|j| j.request.id).collect::<Vec<_>>();
        assert_eq!(ids(&b.next_wave().unwrap()), vec![0, 1]);
        assert_eq!(ids(&b.next_wave().unwrap()), vec![2, 3]);
        assert_eq!(ids(&b.next_wave().unwrap()), vec![4]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn waves_interleave_clients_round_robin() {
        let b = Batcher::new(4, 1024);
        let mut rxs = Vec::new();
        // client 1 floods six requests before client 2's single request
        for id in 0..6 {
            let (j, rx) = job(1, id);
            assert_eq!(b.enqueue(j), Enqueue::Ok);
            rxs.push(rx);
        }
        let (j, rx) = job(2, 100);
        assert_eq!(b.enqueue(j), Enqueue::Ok);
        rxs.push(rx);

        let wave = b.next_wave().unwrap();
        let ids: Vec<i64> = wave.iter().map(|j| j.request.id).collect();
        assert!(
            ids.contains(&100),
            "client 2's request must ride the first wave despite the flood (got {ids:?})"
        );
        // rotation: one job per client per cycle, flood fills the rest
        assert_eq!(ids, vec![0, 100, 1, 2]);
        // remaining flood drains in FIFO order
        let rest: Vec<i64> =
            b.next_wave().unwrap().iter().map(|j| j.request.id).collect();
        assert_eq!(rest, vec![3, 4, 5]);
    }

    #[test]
    fn bounded_queue_sheds_past_max_pending() {
        let b = Batcher::new(8, 2);
        let (j1, _r1) = job(1, 1);
        let (j2, _r2) = job(2, 2);
        assert_eq!(b.enqueue(j1), Enqueue::Ok);
        assert_eq!(b.enqueue(j2), Enqueue::Ok);
        let (j3, _r3) = job(3, 3);
        assert_eq!(b.enqueue(j3), Enqueue::Shed, "third job exceeds max_pending=2");
        // draining makes room again
        assert_eq!(b.next_wave().unwrap().len(), 2);
        let (j4, _r4) = job(3, 4);
        assert_eq!(b.enqueue(j4), Enqueue::Ok);
    }

    #[test]
    fn close_drains_queued_jobs_then_ends() {
        let b = Batcher::new(8, 1024);
        let (j, _rx) = job(1, 1);
        assert_eq!(b.enqueue(j), Enqueue::Ok);
        b.close();
        let (j2, _rx2) = job(1, 2);
        assert_eq!(b.enqueue(j2), Enqueue::Closed, "closed batcher must reject new jobs");
        assert_eq!(b.next_wave().unwrap().len(), 1, "queued job drains after close");
        assert!(b.next_wave().is_none(), "empty + closed ends the dispatcher");
    }

    #[test]
    fn next_wave_blocks_until_work_arrives() {
        let b = Arc::new(Batcher::new(4, 1024));
        let b2 = b.clone();
        let waiter = std::thread::spawn(move || b2.next_wave().map(|w| w.len()));
        std::thread::sleep(std::time::Duration::from_millis(30));
        let (j, _rx) = job(1, 7);
        assert_eq!(b.enqueue(j), Enqueue::Ok);
        assert_eq!(waiter.join().unwrap(), Some(1));
    }
}
