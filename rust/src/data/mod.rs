//! Synthetic-CIFAR substrate.
//!
//! The paper evaluates on CIFAR-10/100 and ImageNet; this environment has no
//! datasets, so FAMES ships a **deterministic procedural image generator**
//! (DESIGN.md §3): each class is a distinct parametric texture family
//! (stripes, checkerboards, blobs, rings, gradients, …) with per-sample
//! jitter + noise. The task is genuinely learnable (a converged model is
//! what Eq. 9's `∂L/∂z ≈ 0` assumption needs) while every FAMES claim being
//! reproduced — perturbation-estimation fidelity, selection optimality,
//! energy ratios — is dataset-shape-independent.
//!
//! Images are CHW f32 in `[0, 1]`; labels are f32 class indices (the PJRT
//! contract is all-f32).

use crate::rng::Pcg;
use crate::tensor::Tensor;

/// A deterministic synthetic classification dataset.
pub struct Dataset {
    pub num_classes: usize,
    pub image_shape: Vec<usize>, // CHW
    seed: u64,
}

/// One batch: images `[B, C, H, W]` and labels `[B]`.
#[derive(Clone, Debug)]
pub struct Batch {
    pub images: Tensor,
    pub labels: Tensor,
}

impl Dataset {
    pub fn new(num_classes: usize, image_shape: &[usize], seed: u64) -> Self {
        assert_eq!(image_shape.len(), 3, "image shape must be CHW");
        Dataset {
            num_classes,
            image_shape: image_shape.to_vec(),
            seed,
        }
    }

    /// The `idx`-th sample (deterministic: same idx ⇒ same sample).
    pub fn sample(&self, idx: u64) -> (Vec<f32>, usize) {
        let mut rng = Pcg::new(self.seed ^ idx.wrapping_mul(0x9e3779b97f4a7c15), idx);
        let label = (idx as usize) % self.num_classes;
        let img = render_class(label % 10, &self.image_shape, &mut rng, label / 10);
        (img, label)
    }

    /// Batch of samples `[start, start + b)` (wrapping over classes evenly).
    pub fn batch(&self, start: u64, b: usize) -> Batch {
        let (c, h, w) = (self.image_shape[0], self.image_shape[1], self.image_shape[2]);
        let mut images = Vec::with_capacity(b * c * h * w);
        let mut labels = Vec::with_capacity(b);
        for i in 0..b {
            let (img, label) = self.sample(start + i as u64);
            images.extend_from_slice(&img);
            labels.push(label as f32);
        }
        Batch {
            images: Tensor::new(vec![b, c, h, w], images).unwrap(),
            labels: Tensor::new(vec![b], labels).unwrap(),
        }
    }

    /// Deterministic shuffled epoch: batch `step` of size `b` drawn from a
    /// window of `pool` samples (distinct permutation per epoch).
    pub fn train_batch(&self, epoch: u64, step: u64, b: usize, pool: u64) -> Batch {
        let mut rng = Pcg::new(self.seed.wrapping_add(epoch * 7919), 17);
        let mut order: Vec<u64> = (0..pool).collect();
        rng.shuffle(&mut order);
        let (c, h, w) = (self.image_shape[0], self.image_shape[1], self.image_shape[2]);
        let mut images = Vec::with_capacity(b * c * h * w);
        let mut labels = Vec::with_capacity(b);
        for i in 0..b {
            let idx = order[((step as usize * b) + i) % pool as usize];
            let (img, label) = self.sample(idx);
            images.extend_from_slice(&img);
            labels.push(label as f32);
        }
        Batch {
            images: Tensor::new(vec![b, c, h, w], images).unwrap(),
            labels: Tensor::new(vec![b], labels).unwrap(),
        }
    }
}

/// Render one image of the given texture family. `variant` perturbs hue for
/// >10-class datasets (CIFAR-100 substitute: 10 families × 10 hues).
fn render_class(family: usize, shape: &[usize], rng: &mut Pcg, variant: usize) -> Vec<f32> {
    let (c, h, w) = (shape[0], shape[1], shape[2]);
    let mut img = vec![0.0f32; c * h * w];
    let hf = h as f64;
    let wf = w as f64;
    // per-sample jitter
    let phase = rng.range_f64(0.0, std::f64::consts::TAU);
    let freq = rng.range_f64(1.5, 2.5);
    let cx = rng.range_f64(0.3, 0.7) * wf;
    let cy = rng.range_f64(0.3, 0.7) * hf;
    // amplitude/noise tuned so a converged mini-CNN lands at ~92–98%
    // accuracy (like the paper's CIFAR models), keeping softmax
    // unsaturated — the Taylor machinery needs non-zero ∂L/∂z.
    let amp = rng.range_f64(0.10, 0.45);
    let noise_sigma = 0.30;
    // per-class base colour rotated by variant (100-class support)
    let hue = family as f64 * 0.61803 + variant as f64 * 0.091;
    let base = [
        0.5 + 0.4 * (hue * std::f64::consts::TAU).sin(),
        0.5 + 0.4 * ((hue + 0.33) * std::f64::consts::TAU).sin(),
        0.5 + 0.4 * ((hue + 0.66) * std::f64::consts::TAU).sin(),
    ];
    for y in 0..h {
        for x in 0..w {
            let xf = x as f64;
            let yf = y as f64;
            let u = xf / wf;
            let v = yf / hf;
            let r = ((xf - cx).powi(2) + (yf - cy).powi(2)).sqrt() / wf;
            let t = match family {
                // vertical stripes
                0 => (freq * 2.0 * std::f64::consts::TAU * u + phase).sin(),
                // horizontal stripes
                1 => (freq * 2.0 * std::f64::consts::TAU * v + phase).sin(),
                // diagonal stripes
                2 => (freq * 2.0 * std::f64::consts::TAU * (u + v) + phase).sin(),
                // checkerboard
                3 => {
                    let sx = ((u * freq * 4.0 + phase).floor() as i64) & 1;
                    let sy = ((v * freq * 4.0).floor() as i64) & 1;
                    if sx ^ sy == 0 { 1.0 } else { -1.0 }
                }
                // centered blob
                4 => (1.0 - 4.0 * r * r).max(-1.0),
                // ring
                5 => (freq * 3.0 * std::f64::consts::TAU * r + phase).cos(),
                // radial gradient
                6 => 1.0 - 2.0 * r,
                // horizontal gradient
                7 => 2.0 * u - 1.0,
                // grid of dots
                8 => {
                    let du = (u * freq * 3.0 + phase / 6.0).fract() - 0.5;
                    let dv = (v * freq * 3.0).fract() - 0.5;
                    if du * du + dv * dv < 0.05 { 1.0 } else { -0.6 }
                }
                // cross / plus sign
                _ => {
                    let near_x = (xf - cx).abs() < wf * 0.12;
                    let near_y = (yf - cy).abs() < hf * 0.12;
                    if near_x || near_y { 1.0 } else { -0.8 }
                }
            };
            for ch in 0..c {
                let noise = rng.normal() * noise_sigma;
                let val = base[ch % 3] + amp * 0.45 * t * if ch % 2 == 0 { 1.0 } else { 0.8 }
                    + noise;
                img[ch * h * w + y * w + x] = val.clamp(0.0, 1.0) as f32;
            }
        }
    }
    img
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_samples() {
        let ds = Dataset::new(10, &[3, 16, 16], 7);
        let (a, la) = ds.sample(5);
        let (b, lb) = ds.sample(5);
        assert_eq!(a, b);
        assert_eq!(la, lb);
        let (c, _) = ds.sample(6);
        assert_ne!(a, c);
    }

    #[test]
    fn batch_shapes_and_range() {
        let ds = Dataset::new(10, &[3, 16, 16], 0);
        let b = ds.batch(0, 8);
        assert_eq!(b.images.shape(), &[8, 3, 16, 16]);
        assert_eq!(b.labels.shape(), &[8]);
        for &v in b.images.data() {
            assert!((0.0..=1.0).contains(&v));
        }
        // labels cycle through classes
        assert_eq!(b.labels.data()[0], 0.0);
        assert_eq!(b.labels.data()[1], 1.0);
    }

    #[test]
    fn classes_are_distinguishable_in_pixel_space() {
        // Nearest-centroid accuracy on raw pixels must beat chance by a lot
        // — otherwise the task is not learnable and every accuracy
        // experiment downstream is meaningless.
        let ds = Dataset::new(10, &[3, 16, 16], 1);
        let dim = 3 * 16 * 16;
        let n_train = 400u64;
        let mut centroids = vec![vec![0.0f64; dim]; 10];
        let mut counts = vec![0usize; 10];
        for i in 0..n_train {
            let (img, label) = ds.sample(i);
            for (j, &v) in img.iter().enumerate() {
                centroids[label][j] += v as f64;
            }
            counts[label] += 1;
        }
        for (c, cnt) in centroids.iter_mut().zip(&counts) {
            for v in c.iter_mut() {
                *v /= *cnt as f64;
            }
        }
        let mut correct = 0;
        let n_test = 200u64;
        for i in n_train..n_train + n_test {
            let (img, label) = ds.sample(i);
            let mut best = (f64::MAX, 0usize);
            for (k, c) in centroids.iter().enumerate() {
                let d: f64 = img
                    .iter()
                    .zip(c.iter())
                    .map(|(&a, &b)| (a as f64 - b).powi(2))
                    .sum();
                if d < best.0 {
                    best = (d, k);
                }
            }
            if best.1 == label {
                correct += 1;
            }
        }
        let acc = correct as f64 / n_test as f64;
        assert!(acc > 0.5, "nearest-centroid accuracy only {acc}");
    }

    #[test]
    fn hundred_class_variant_labels() {
        let ds = Dataset::new(100, &[3, 16, 16], 2);
        let b = ds.batch(0, 128);
        let max = b.labels.data().iter().cloned().fold(0.0f32, f32::max);
        assert_eq!(max, 99.0);
    }

    #[test]
    fn train_batches_differ_across_epochs() {
        let ds = Dataset::new(10, &[3, 16, 16], 3);
        let a = ds.train_batch(0, 0, 16, 256);
        let b = ds.train_batch(1, 0, 16, 256);
        assert_ne!(a.images.data(), b.images.data());
    }
}
