//! Adder building blocks: half/full adders (exact and approximate) and
//! ripple-carry vectors, used by the array-multiplier generators.
//!
//! The approximate full adder is the classic "lower-part OR" style
//! approximation used throughout the AppMul literature (e.g. the
//! EvoApprox8b seeds): `sum = a | b | cin`-family cells that trade XOR
//! stacks for single OR gates.

use super::cell::CellKind;
use super::netlist::{NetId, Netlist};

/// sum/carry of a half adder.
pub fn half_adder(n: &mut Netlist, a: NetId, b: NetId) -> (NetId, NetId) {
    let sum = n.gate(CellKind::Xor2, a, b);
    let carry = n.gate(CellKind::And2, a, b);
    (sum, carry)
}

/// sum/carry of an exact full adder (two XORs, two ANDs, one OR).
pub fn full_adder(n: &mut Netlist, a: NetId, b: NetId, cin: NetId) -> (NetId, NetId) {
    let axb = n.gate(CellKind::Xor2, a, b);
    let sum = n.gate(CellKind::Xor2, axb, cin);
    let t1 = n.gate(CellKind::And2, a, b);
    let t2 = n.gate(CellKind::And2, axb, cin);
    let carry = n.gate(CellKind::Or2, t1, t2);
    (sum, carry)
}

/// Approximate full adder: `sum ≈ a ⊕ b | cin-ish` single-gate forms.
/// This is the "AFA" used by the approximate-compressor multiplier family:
/// sum = (a | b) ⊕ cin is replaced by sum = a | b | cin and
/// carry = majority is replaced by carry = a & b — 3 cheap gates total.
pub fn approx_full_adder(n: &mut Netlist, a: NetId, b: NetId, cin: NetId) -> (NetId, NetId) {
    let ab = n.gate(CellKind::Or2, a, b);
    let sum = n.gate(CellKind::Or2, ab, cin);
    let carry = n.gate(CellKind::And2, a, b);
    (sum, carry)
}

/// Ripple-carry addition of two equal-width little-endian vectors; returns
/// `width + 1` sum bits (the MSB is the carry out).
pub fn ripple_carry(n: &mut Netlist, a: &[NetId], b: &[NetId]) -> Vec<NetId> {
    assert_eq!(a.len(), b.len());
    let mut out = Vec::with_capacity(a.len() + 1);
    let mut carry: Option<NetId> = None;
    for i in 0..a.len() {
        let (s, c) = match carry {
            None => half_adder(n, a[i], b[i]),
            Some(cin) => full_adder(n, a[i], b[i], cin),
        };
        out.push(s);
        carry = Some(c);
    }
    out.push(carry.unwrap());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn eval_bits(bits: &[bool]) -> u64 {
        bits.iter()
            .enumerate()
            .map(|(i, &b)| (b as u64) << i)
            .sum()
    }

    #[test]
    fn full_adder_truth_table() {
        for i in 0..8u32 {
            let mut n = Netlist::new(3);
            let (s, c) = full_adder(&mut n, 0, 1, 2);
            n.set_outputs(vec![s, c]);
            let a = i & 1 != 0;
            let b = i & 2 != 0;
            let cin = i & 4 != 0;
            let out = n.eval(&[a, b, cin]);
            let want = a as u32 + b as u32 + cin as u32;
            assert_eq!(out[0] as u32, want & 1);
            assert_eq!(out[1] as u32, want >> 1);
        }
    }

    #[test]
    fn half_adder_truth_table() {
        for i in 0..4u32 {
            let mut n = Netlist::new(2);
            let (s, c) = half_adder(&mut n, 0, 1);
            n.set_outputs(vec![s, c]);
            let a = i & 1 != 0;
            let b = i & 2 != 0;
            let out = n.eval(&[a, b]);
            let want = a as u32 + b as u32;
            assert_eq!(out[0] as u32, want & 1);
            assert_eq!(out[1] as u32, want >> 1);
        }
    }

    #[test]
    fn approx_full_adder_is_cheaper_and_close() {
        // cost comparison
        let mut ne = Netlist::new(3);
        let (s, c) = full_adder(&mut ne, 0, 1, 2);
        ne.set_outputs(vec![s, c]);
        let mut na = Netlist::new(3);
        let (s, c) = approx_full_adder(&mut na, 0, 1, 2);
        na.set_outputs(vec![s, c]);
        assert!(na.area() < ne.area());
        assert!(na.critical_path_ps() < ne.critical_path_ps());
        // functional distance: wrong on a minority of the 8 input rows
        let mut wrong = 0;
        for i in 0..8u32 {
            let bits = [i & 1 != 0, i & 2 != 0, i & 4 != 0];
            let want = bits.iter().map(|&b| b as u32).sum::<u32>();
            let out = na.eval(&bits);
            let got = out[0] as u32 + 2 * out[1] as u32;
            if got != want {
                wrong += 1;
            }
        }
        assert!(wrong > 0 && wrong <= 3, "wrong rows: {wrong}");
    }

    #[test]
    fn ripple_carry_exhaustive_4bit() {
        let mut n = Netlist::new(8);
        let a: Vec<NetId> = (0..4).collect();
        let b: Vec<NetId> = (4..8).collect();
        let sum = ripple_carry(&mut n, &a, &b);
        n.set_outputs(sum);
        for x in 0..16u64 {
            for y in 0..16u64 {
                let mut bits = [false; 8];
                for i in 0..4 {
                    bits[i] = x >> i & 1 != 0;
                    bits[4 + i] = y >> i & 1 != 0;
                }
                let out = n.eval(&bits);
                assert_eq!(eval_bits(&out), x + y, "{x}+{y}");
            }
        }
    }
}
