//! Gate-level circuit substrate.
//!
//! Stand-in for the paper's hardware characterization flow (Synopsys Design
//! Compiler + NanGate 45nm): cell library ([`cell`]), netlist construction /
//! simulation / timing / switching-energy analysis ([`netlist`]), adder
//! blocks ([`adders`]) and array-multiplier generators with structural
//! approximation knobs ([`multiplier`]). The AppMul library (`crate::appmul`)
//! is generated entirely from these netlists: LUTs by exhaustive simulation,
//! PDP by Monte-Carlo toggle counting × critical-path delay.

pub mod adders;
pub mod cell;
pub mod multiplier;
pub mod netlist;

pub use cell::{CellCost, CellKind};
pub use multiplier::{build_lut, build_multiplier, eval_mult, MulConfig};
pub use netlist::{Gate, NetId, Netlist};
