//! Standard-cell cost table — NanGate-45nm-like typical values.
//!
//! Stand-in for the paper's Synopsys Design Compiler + NanGate 45nm Open
//! Cell Library characterization (DESIGN.md §3). Values are representative
//! of the NanGate45 typical corner (area in µm², delay in ps, internal +
//! switching energy per output toggle in fJ); what matters for the paper's
//! claims is the *relative* PDP across multiplier variants and bitwidths,
//! which these preserve (array-multiplier PDP grows ≈N³: N² cells × N
//! critical path).

/// Combinational cell kinds used by the multiplier generators.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CellKind {
    Inv,
    Buf,
    And2,
    Or2,
    Nand2,
    Nor2,
    Xor2,
    Xnor2,
    /// Constant 0/1 driver (used by pruning transforms); zero cost.
    Const,
}

/// Per-cell characterization.
#[derive(Clone, Copy, Debug)]
pub struct CellCost {
    /// Cell area, µm².
    pub area: f64,
    /// Pin-to-pin propagation delay, ps.
    pub delay: f64,
    /// Energy per output toggle, fJ.
    pub energy: f64,
}

impl CellKind {
    /// NanGate-45-like typical-corner characterization.
    pub fn cost(self) -> CellCost {
        // (area µm², delay ps, energy fJ/toggle)
        let (area, delay, energy) = match self {
            CellKind::Inv => (0.53, 12.0, 0.35),
            CellKind::Buf => (0.80, 18.0, 0.50),
            CellKind::And2 => (1.06, 32.0, 0.75),
            CellKind::Or2 => (1.06, 33.0, 0.78),
            CellKind::Nand2 => (0.80, 22.0, 0.55),
            CellKind::Nor2 => (0.80, 24.0, 0.58),
            CellKind::Xor2 => (1.60, 45.0, 1.20),
            CellKind::Xnor2 => (1.60, 46.0, 1.22),
            CellKind::Const => (0.0, 0.0, 0.0),
        };
        CellCost { area, delay, energy }
    }

    /// Number of data inputs the kind consumes.
    pub fn arity(self) -> usize {
        match self {
            CellKind::Inv | CellKind::Buf => 1,
            CellKind::Const => 0,
            _ => 2,
        }
    }

    /// Evaluate the boolean function. `b` is ignored for unary cells; for
    /// `Const`, `a` carries the constant value.
    #[inline]
    pub fn eval(self, a: bool, b: bool) -> bool {
        match self {
            CellKind::Inv => !a,
            CellKind::Buf => a,
            CellKind::And2 => a & b,
            CellKind::Or2 => a | b,
            CellKind::Nand2 => !(a & b),
            CellKind::Nor2 => !(a | b),
            CellKind::Xor2 => a ^ b,
            CellKind::Xnor2 => !(a ^ b),
            CellKind::Const => a,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn truth_tables() {
        use CellKind::*;
        for (k, table) in [
            (And2, [false, false, false, true]),
            (Or2, [false, true, true, true]),
            (Nand2, [true, true, true, false]),
            (Nor2, [true, false, false, false]),
            (Xor2, [false, true, true, false]),
            (Xnor2, [true, false, false, true]),
        ] {
            for (i, want) in table.iter().enumerate() {
                let a = i & 2 != 0;
                let b = i & 1 != 0;
                assert_eq!(k.eval(a, b), *want, "{k:?} {a} {b}");
            }
        }
        assert!(Inv.eval(false, false));
        assert!(!Inv.eval(true, false));
        assert!(Buf.eval(true, false));
    }

    #[test]
    fn xor_is_most_expensive_two_input() {
        let xor = CellKind::Xor2.cost();
        for k in [CellKind::And2, CellKind::Or2, CellKind::Nand2, CellKind::Nor2] {
            assert!(xor.delay > k.cost().delay);
            assert!(xor.energy > k.cost().energy);
        }
    }
}
