//! Unsigned array-multiplier generators (exact + structural approximations).
//!
//! The generator builds a partial-product AND matrix and reduces it
//! column-wise with full/half adders (Wallace-style 3:2 reduction) followed
//! by a final ripple adder — the same structure the AppMul literature
//! approximates. Three structural knobs mirror the classic approximation
//! families:
//!
//! * `trunc_cols` — drop all partial products in the lowest columns
//!   (LSB truncation, the EvoApprox "trunc" family);
//! * `perf_rows`  — skip whole partial-product rows (perforation);
//! * `approx_cols` — use the cheap OR-based approximate full adder for
//!   reductions in the lowest columns (approximate-compressor family).

use super::adders::{approx_full_adder, full_adder, ripple_carry};
use super::cell::CellKind;
use super::netlist::{NetId, Netlist};

/// Configuration of one generated multiplier.
#[derive(Clone, Debug, Default)]
pub struct MulConfig {
    pub a_bits: u32,
    pub w_bits: u32,
    /// Zero out partial products in columns `< trunc_cols`.
    pub trunc_cols: u32,
    /// Skip partial-product rows with these indices (0 = LSB row of w).
    pub perf_rows: Vec<u32>,
    /// Use the approximate full adder for columns `< approx_cols`.
    pub approx_cols: u32,
}

impl MulConfig {
    pub fn exact(a_bits: u32, w_bits: u32) -> Self {
        MulConfig {
            a_bits,
            w_bits,
            ..Default::default()
        }
    }
}

/// Build the netlist for a configuration. Inputs are little-endian:
/// nets `0..a_bits` = multiplicand, `a_bits..a_bits+w_bits` = multiplier.
/// Outputs are the `a_bits + w_bits` product bits, little-endian.
pub fn build_multiplier(cfg: &MulConfig) -> Netlist {
    let (na, nw) = (cfg.a_bits as usize, cfg.w_bits as usize);
    let total = na + nw;
    let mut n = Netlist::new(na + nw);
    // Partial-product matrix, bucketed by output column.
    let mut cols: Vec<Vec<NetId>> = vec![Vec::new(); total];
    for j in 0..nw {
        if cfg.perf_rows.contains(&(j as u32)) {
            continue;
        }
        for i in 0..na {
            let col = i + j;
            if (col as u32) < cfg.trunc_cols {
                continue;
            }
            let pp = n.gate(CellKind::And2, i, na + j);
            cols[col].push(pp);
        }
    }
    // Column-wise 3:2 / 2:2 reduction until every column holds ≤ 2 bits.
    for c in 0..total {
        while cols[c].len() > 2 {
            if cols[c].len() >= 3 {
                let x = cols[c].pop().unwrap();
                let y = cols[c].pop().unwrap();
                let z = cols[c].pop().unwrap();
                let (s, carry) = if (c as u32) < cfg.approx_cols {
                    approx_full_adder(&mut n, x, y, z)
                } else {
                    full_adder(&mut n, x, y, z)
                };
                cols[c].push(s);
                if c + 1 < total {
                    cols[c + 1].push(carry);
                }
            }
        }
    }
    // Final ripple adder over the two remaining rows.
    let zero = n.constant(false);
    let row1: Vec<NetId> = (0..total)
        .map(|c| cols[c].first().copied().unwrap_or(zero))
        .collect();
    let row2: Vec<NetId> = (0..total)
        .map(|c| cols[c].get(1).copied().unwrap_or(zero))
        .collect();
    let mut sum = ripple_carry(&mut n, &row1, &row2);
    sum.truncate(total); // a·w < 2^(na+nw): the final carry is always 0
    n.set_outputs(sum);
    n
}

/// Evaluate a multiplier netlist on integer operands.
pub fn eval_mult(n: &Netlist, a_bits: u32, w_bits: u32, a: u64, w: u64) -> u64 {
    let mut bits = Vec::with_capacity((a_bits + w_bits) as usize);
    for i in 0..a_bits {
        bits.push(a >> i & 1 != 0);
    }
    for j in 0..w_bits {
        bits.push(w >> j & 1 != 0);
    }
    let out = n.eval(&bits);
    out.iter()
        .enumerate()
        .map(|(i, &b)| (b as u64) << i)
        .sum()
}

/// Exhaustive LUT: `lut[a · 2^w_bits + w] = netlist(a, w)`, computed with
/// 64-lane word-parallel sweeps (the hot path of library generation: one
/// 8×8 LUT costs 1024 sweeps instead of 65536 scalar evaluations).
pub fn build_lut(n: &Netlist, a_bits: u32, w_bits: u32) -> Vec<i64> {
    let total_bits = (a_bits + w_bits) as usize;
    let rows = 1usize << total_bits;
    let mut lut = vec![0i64; rows];
    let mut inputs = vec![0u64; total_bits];
    let mut nets = Vec::with_capacity(n.n_nets());
    let mut base = 0usize;
    while base < rows {
        let lanes = 64.min(rows - base);
        // lane L carries input row (base + L); input bit i of that row is
        // bit i of the row index (a in low bits? No: row = a·2^w + w, and
        // the netlist wants a little-endian then w little-endian).
        for (i, word) in inputs.iter_mut().enumerate() {
            let mut v = 0u64;
            for lane in 0..lanes {
                let row = base + lane;
                let a = (row >> w_bits) as u64;
                let w = (row as u64) & ((1 << w_bits) - 1);
                let bit = if i < a_bits as usize {
                    a >> i & 1
                } else {
                    w >> (i - a_bits as usize) & 1
                };
                v |= bit << lane;
            }
            *word = v;
        }
        n.eval_words(&inputs, &mut nets);
        for lane in 0..lanes {
            let mut v = 0i64;
            for (i, &o) in n.outputs.iter().enumerate() {
                v |= ((nets[o] >> lane & 1) as i64) << i;
            }
            lut[base + lane] = v;
        }
        base += lanes;
    }
    lut
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_multipliers_exhaustive() {
        for (a_bits, w_bits) in [(2, 2), (3, 3), (4, 4), (2, 4), (5, 3)] {
            let n = build_multiplier(&MulConfig::exact(a_bits, w_bits));
            for a in 0..1u64 << a_bits {
                for w in 0..1u64 << w_bits {
                    assert_eq!(
                        eval_mult(&n, a_bits, w_bits, a, w),
                        a * w,
                        "{a_bits}x{w_bits}: {a}*{w}"
                    );
                }
            }
        }
    }

    #[test]
    fn exact_8x8_spot_checks() {
        let n = build_multiplier(&MulConfig::exact(8, 8));
        for (a, w) in [(0, 0), (255, 255), (255, 1), (127, 2), (200, 99), (13, 17)] {
            assert_eq!(eval_mult(&n, 8, 8, a, w), a * w);
        }
    }

    #[test]
    fn lut_matches_eval() {
        let cfg = MulConfig::exact(3, 3);
        let n = build_multiplier(&cfg);
        let lut = build_lut(&n, 3, 3);
        for a in 0..8u64 {
            for w in 0..8u64 {
                assert_eq!(lut[(a * 8 + w) as usize] as u64, a * w);
            }
        }
    }

    #[test]
    fn truncation_underestimates_and_saves() {
        let exact = build_multiplier(&MulConfig::exact(4, 4));
        let cfg = MulConfig {
            trunc_cols: 3,
            ..MulConfig::exact(4, 4)
        };
        let trunc = build_multiplier(&cfg);
        assert!(trunc.area() < exact.area());
        let mut any_err = false;
        for a in 0..16u64 {
            for w in 0..16u64 {
                let t = eval_mult(&trunc, 4, 4, a, w);
                assert!(t <= a * w, "truncation must underestimate");
                // dropped columns bound the error below 2^trunc_cols scaled
                // by the number of dropped diagonals
                assert!(a * w - t < 64, "error too large: {a}*{w}={t}");
                any_err |= t != a * w;
            }
        }
        assert!(any_err);
    }

    #[test]
    fn perforation_drops_row_contribution() {
        let cfg = MulConfig {
            perf_rows: vec![0],
            ..MulConfig::exact(4, 4)
        };
        let n = build_multiplier(&cfg);
        for a in 0..16u64 {
            for w in 0..16u64 {
                // dropping w's LSB row computes a · (w & !1)
                assert_eq!(eval_mult(&n, 4, 4, a, w), a * (w & !1));
            }
        }
    }

    #[test]
    fn approx_compressor_cheaper_with_bounded_error() {
        let exact = build_multiplier(&MulConfig::exact(4, 4));
        let cfg = MulConfig {
            approx_cols: 4,
            ..MulConfig::exact(4, 4)
        };
        let ap = build_multiplier(&cfg);
        assert!(ap.area() < exact.area());
        let mut max_rel: f64 = 0.0;
        for a in 1..16u64 {
            for w in 1..16u64 {
                let got = eval_mult(&ap, 4, 4, a, w) as f64;
                let want = (a * w) as f64;
                max_rel = max_rel.max((got - want).abs() / want);
            }
        }
        assert!(max_rel > 0.0 && max_rel < 1.5, "max rel err {max_rel}");
    }

    #[test]
    fn pdp_scales_superlinearly_with_bitwidth() {
        // DESIGN.md §3: the relative-energy columns of Table III rest on
        // PDP(8b) ≫ PDP(4b) ≫ PDP(2b).
        let pdp = |bits: u32| {
            let n = build_multiplier(&MulConfig::exact(bits, bits));
            n.pdp_fj(512, 1) * n.critical_path_ps()
        };
        let (p2, p4, p8) = (pdp(2), pdp(4), pdp(8));
        assert!(p4 > 4.0 * p2, "p4={p4} p2={p2}");
        assert!(p8 > 4.0 * p4, "p8={p8} p4={p4}");
    }
}
