//! Gate-level netlist: construction, simulation, timing, area and energy.
//!
//! The netlist is kept in topological order by construction (a gate may only
//! reference already-existing nets), so evaluation, arrival-time analysis
//! and toggle counting are single forward sweeps.

use anyhow::{bail, Result};

use super::cell::CellKind;
use crate::rng::Pcg;

/// Net index: `0..n_inputs` are primary inputs; each gate drives net
/// `n_inputs + gate_index`.
pub type NetId = usize;

/// One gate instance. For `CellKind::Const`, `a` holds the constant (0/1).
#[derive(Clone, Copy, Debug)]
pub struct Gate {
    pub kind: CellKind,
    pub a: NetId,
    pub b: NetId,
}

/// A combinational netlist.
#[derive(Clone, Debug)]
pub struct Netlist {
    pub n_inputs: usize,
    pub gates: Vec<Gate>,
    pub outputs: Vec<NetId>,
}

impl Netlist {
    pub fn new(n_inputs: usize) -> Self {
        Netlist {
            n_inputs,
            gates: Vec::new(),
            outputs: Vec::new(),
        }
    }

    pub fn n_nets(&self) -> usize {
        self.n_inputs + self.gates.len()
    }

    /// Add a gate; returns the net it drives. Panics on forward references
    /// (programmer error — builders construct in topological order).
    pub fn gate(&mut self, kind: CellKind, a: NetId, b: NetId) -> NetId {
        let limit = self.n_nets();
        assert!(a < limit && (kind.arity() < 2 || b < limit), "forward net reference");
        self.gates.push(Gate { kind, a, b });
        limit
    }

    /// Constant-0 / constant-1 net.
    pub fn constant(&mut self, value: bool) -> NetId {
        self.gates.push(Gate {
            kind: CellKind::Const,
            a: value as usize,
            b: 0,
        });
        self.n_nets() - 1
    }

    pub fn set_outputs(&mut self, outs: Vec<NetId>) {
        self.outputs = outs;
    }

    /// Evaluate all nets for the given primary-input values.
    pub fn eval_nets(&self, inputs: &[bool], nets: &mut Vec<bool>) {
        debug_assert_eq!(inputs.len(), self.n_inputs);
        nets.clear();
        nets.extend_from_slice(inputs);
        for g in &self.gates {
            let v = match g.kind {
                CellKind::Const => g.a != 0,
                k if k.arity() == 1 => k.eval(nets[g.a], false),
                k => k.eval(nets[g.a], nets[g.b]),
            };
            nets.push(v);
        }
    }

    /// Evaluate primary outputs.
    pub fn eval(&self, inputs: &[bool]) -> Vec<bool> {
        let mut nets = Vec::with_capacity(self.n_nets());
        self.eval_nets(inputs, &mut nets);
        self.outputs.iter().map(|&o| nets[o]).collect()
    }

    /// Gates transitively reachable from the outputs (dead logic excluded
    /// from every cost metric — pruning transforms rely on this).
    pub fn live_gates(&self) -> Vec<bool> {
        let mut live_net = vec![false; self.n_nets()];
        for &o in &self.outputs {
            live_net[o] = true;
        }
        for (gi, g) in self.gates.iter().enumerate().rev() {
            let net = self.n_inputs + gi;
            if !live_net[net] || g.kind == CellKind::Const {
                continue;
            }
            live_net[g.a] = true;
            if g.kind.arity() == 2 {
                live_net[g.b] = true;
            }
        }
        (0..self.gates.len())
            .map(|gi| live_net[self.n_inputs + gi])
            .collect()
    }

    /// Number of live (cost-bearing) gates.
    pub fn live_gate_count(&self) -> usize {
        let live = self.live_gates();
        self.gates
            .iter()
            .zip(&live)
            .filter(|(g, &l)| l && g.kind != CellKind::Const)
            .count()
    }

    /// Total cell area (µm²) over live gates.
    pub fn area(&self) -> f64 {
        let live = self.live_gates();
        self.gates
            .iter()
            .zip(&live)
            .filter(|(_, &l)| l)
            .map(|(g, _)| g.kind.cost().area)
            .sum()
    }

    /// Critical-path delay (ps): longest arrival time at any output.
    pub fn critical_path_ps(&self) -> f64 {
        let live = self.live_gates();
        let mut arrival = vec![0.0f64; self.n_nets()];
        for (gi, g) in self.gates.iter().enumerate() {
            let net = self.n_inputs + gi;
            if !live[gi] || g.kind == CellKind::Const {
                continue;
            }
            let t_in = if g.kind.arity() == 2 {
                arrival[g.a].max(arrival[g.b])
            } else {
                arrival[g.a]
            };
            arrival[net] = t_in + g.kind.cost().delay;
        }
        self.outputs
            .iter()
            .map(|&o| arrival[o])
            .fold(0.0, f64::max)
    }

    /// Average switching energy per operation (fJ), by toggle-counting over
    /// random input transitions (Monte-Carlo switching-activity model: each
    /// output toggle of a live gate costs that cell's per-toggle energy).
    pub fn switching_energy_fj(&self, transitions: usize, seed: u64) -> f64 {
        let mut rng = Pcg::seeded(seed ^ 0x5eed);
        let live = self.live_gates();
        let mut prev = vec![false; self.n_nets()];
        let mut cur = Vec::with_capacity(self.n_nets());
        let mut inputs = vec![false; self.n_inputs];
        // initial state
        for v in inputs.iter_mut() {
            *v = rng.chance(0.5);
        }
        self.eval_nets(&inputs.clone(), &mut cur);
        std::mem::swap(&mut prev, &mut cur);
        let mut total = 0.0;
        for _ in 0..transitions {
            for v in inputs.iter_mut() {
                *v = rng.chance(0.5);
            }
            self.eval_nets(&inputs.clone(), &mut cur);
            for (gi, g) in self.gates.iter().enumerate() {
                if !live[gi] || g.kind == CellKind::Const {
                    continue;
                }
                let net = self.n_inputs + gi;
                if prev[net] != cur[net] {
                    total += g.kind.cost().energy;
                }
            }
            std::mem::swap(&mut prev, &mut cur);
        }
        total / transitions as f64
    }

    /// Power-delay product proxy (fJ): average energy per operation. The
    /// paper's `Energy(k, AM) = PDP · #mults` uses exactly this quantity.
    pub fn pdp_fj(&self, transitions: usize, seed: u64) -> f64 {
        self.switching_energy_fj(transitions, seed)
    }

    /// Bit-parallel evaluation: every net is a 64-lane word, so one sweep
    /// simulates 64 independent input vectors. This is the hot path of LUT
    /// extraction (2^16 rows for 8×8) and of the ALSRAC-style pruning loop.
    pub fn eval_words(&self, inputs: &[u64], nets: &mut Vec<u64>) {
        debug_assert_eq!(inputs.len(), self.n_inputs);
        nets.clear();
        nets.extend_from_slice(inputs);
        for g in &self.gates {
            let v = match g.kind {
                CellKind::Const => {
                    if g.a != 0 {
                        !0u64
                    } else {
                        0u64
                    }
                }
                CellKind::Inv => !nets[g.a],
                CellKind::Buf => nets[g.a],
                CellKind::And2 => nets[g.a] & nets[g.b],
                CellKind::Or2 => nets[g.a] | nets[g.b],
                CellKind::Nand2 => !(nets[g.a] & nets[g.b]),
                CellKind::Nor2 => !(nets[g.a] | nets[g.b]),
                CellKind::Xor2 => nets[g.a] ^ nets[g.b],
                CellKind::Xnor2 => !(nets[g.a] ^ nets[g.b]),
            };
            nets.push(v);
        }
    }

    /// Word-parallel switching energy: `pairs` random (before, after) input
    /// transitions per 64-lane sweep; toggles counted with popcount.
    pub fn switching_energy_words_fj(&self, sweeps: usize, seed: u64) -> f64 {
        let mut rng = Pcg::seeded(seed ^ 0x5eed);
        let live = self.live_gates();
        let mut in_a = vec![0u64; self.n_inputs];
        let mut in_b = vec![0u64; self.n_inputs];
        let mut nets_a = Vec::with_capacity(self.n_nets());
        let mut nets_b = Vec::with_capacity(self.n_nets());
        let mut total = 0.0;
        for _ in 0..sweeps {
            for v in in_a.iter_mut() {
                *v = rng.next_u64();
            }
            for v in in_b.iter_mut() {
                *v = rng.next_u64();
            }
            self.eval_words(&in_a, &mut nets_a);
            self.eval_words(&in_b, &mut nets_b);
            for (gi, g) in self.gates.iter().enumerate() {
                if !live[gi] || g.kind == CellKind::Const {
                    continue;
                }
                let net = self.n_inputs + gi;
                let toggles = (nets_a[net] ^ nets_b[net]).count_ones() as f64;
                total += toggles * g.kind.cost().energy;
            }
        }
        total / (sweeps * 64) as f64
    }

    /// Replace gate `gi`'s output with a constant (ALSRAC-style stuck-at
    /// simplification). Downstream logic keeps indices; dead fan-in is
    /// excluded from costs automatically via the live set.
    pub fn stuck_at(&mut self, gi: usize, value: bool) -> Result<()> {
        if gi >= self.gates.len() {
            bail!("gate index {gi} out of range");
        }
        self.gates[gi] = Gate {
            kind: CellKind::Const,
            a: value as usize,
            b: 0,
        };
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// c = (a AND b) XOR a
    fn tiny() -> Netlist {
        let mut n = Netlist::new(2);
        let ab = n.gate(CellKind::And2, 0, 1);
        let x = n.gate(CellKind::Xor2, ab, 0);
        n.set_outputs(vec![x]);
        n
    }

    #[test]
    fn eval_tiny() {
        let n = tiny();
        // (a&b)^a: 00->0 01->0 10->1 11->0
        assert_eq!(n.eval(&[false, false]), vec![false]);
        assert_eq!(n.eval(&[false, true]), vec![false]);
        assert_eq!(n.eval(&[true, false]), vec![true]);
        assert_eq!(n.eval(&[true, true]), vec![false]);
    }

    #[test]
    fn delay_is_path_sum() {
        let n = tiny();
        let want = CellKind::And2.cost().delay + CellKind::Xor2.cost().delay;
        assert_eq!(n.critical_path_ps(), want);
    }

    #[test]
    fn area_counts_live_only() {
        let mut n = tiny();
        // dead gate: not on any output path
        n.gate(CellKind::Or2, 0, 1);
        let want = CellKind::And2.cost().area + CellKind::Xor2.cost().area;
        assert_eq!(n.area(), want);
        assert_eq!(n.live_gate_count(), 2);
    }

    #[test]
    fn stuck_at_simplifies() {
        let mut n = tiny();
        n.stuck_at(0, false).unwrap(); // and-gate → const 0 ⇒ out = a
        assert_eq!(n.eval(&[true, true]), vec![true]);
        assert_eq!(n.eval(&[false, true]), vec![false]);
        // the AND's cost disappears
        assert_eq!(n.area(), CellKind::Xor2.cost().area);
    }

    #[test]
    fn switching_energy_positive_and_deterministic() {
        let n = tiny();
        let e1 = n.switching_energy_fj(256, 9);
        let e2 = n.switching_energy_fj(256, 9);
        assert_eq!(e1, e2);
        assert!(e1 > 0.0);
        // can't exceed every live gate toggling every transition
        let cap = CellKind::And2.cost().energy + CellKind::Xor2.cost().energy;
        assert!(e1 <= cap);
    }

    #[test]
    fn word_eval_matches_scalar_eval() {
        let n = tiny();
        // lanes: all 4 input combinations
        let a_word = 0b1100u64;
        let b_word = 0b1010u64;
        let mut nets = Vec::new();
        n.eval_words(&[a_word, b_word], &mut nets);
        for lane in 0..4 {
            let a = a_word >> lane & 1 != 0;
            let b = b_word >> lane & 1 != 0;
            let want = n.eval(&[a, b])[0];
            let got = nets[n.outputs[0]] >> lane & 1 != 0;
            assert_eq!(got, want, "lane {lane}");
        }
    }

    #[test]
    fn word_switching_energy_close_to_scalar() {
        let n = tiny();
        let scalar = n.switching_energy_fj(4096, 11);
        let words = n.switching_energy_words_fj(64, 11);
        let rel = (scalar - words).abs() / scalar;
        assert!(rel < 0.15, "scalar {scalar} vs words {words}");
    }

    #[test]
    fn constant_nets_cost_nothing() {
        let mut n = Netlist::new(1);
        let c1 = n.constant(true);
        let o = n.gate(CellKind::And2, 0, c1);
        n.set_outputs(vec![o]);
        assert_eq!(n.eval(&[true]), vec![true]);
        assert_eq!(n.eval(&[false]), vec![false]);
        assert_eq!(n.area(), CellKind::And2.cost().area);
    }
}
