//! Taylor-expansion perturbation estimation (paper §IV-C).
//!
//! `Ω(k, AM) ≈ gₖ·e + ½ eᵀ Hₖ e` with `g = ∇_E L` fetched from the `grad_e`
//! artifact (one backprop — the gather transpose *is* the counting-matrix
//! sum of Eq. 10) and `Hₖ` approximated by its top eigenpair `λₖ uₖuₖᵀ`
//! (Eq. 12), obtained by **power iteration** on the exact Gauss–Newton
//! Hessian-vector products of the `hvp_e` artifact.
//!
//! Everything here is computed **once per model**; evaluating a candidate
//! AppMul is then two dot products (the paper's headline speed-up over
//! GA-based selection). Per-layer power iterations and the per-(layer,
//! candidate) exact-HVP probes are independent, so both fan out across the
//! `util::par` worker threads (`Session::jobs`) with bit-identical results
//! at every worker count.

use anyhow::{bail, Result};

use crate::appmul::{AppMul, Library};
use crate::pipeline::session::Session;
use crate::tensor::Tensor;
use crate::util::par;

/// How the second-order term of Eq. 9 is computed.
///
/// ```
/// use fames::sensitivity::HessianMode;
/// // the paper's Eq. 12 rank-1 approximation, 6 power iterations
/// let mode = HessianMode::Rank1 { iters: 6 };
/// assert_ne!(mode, HessianMode::Off);
/// assert_eq!(mode, HessianMode::Rank1 { iters: 6 });
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HessianMode {
    /// First-order only (`Ω = g·e`).
    Off,
    /// Rank-1 top-eigenpair approximation (paper Eq. 12; power iteration).
    Rank1 { iters: usize },
    /// Exact Gauss–Newton quadratic per candidate: `½ e·(H e)` via one
    /// HVP per (layer, candidate) — the paper's §IV-C2 ("accurate but
    /// slower") variant; at this model scale it costs seconds, not hours,
    /// and is the pipeline default.
    Exact,
}

/// Per-layer estimation state.
#[derive(Clone, Debug)]
pub struct LayerEstimate {
    /// ∇_E L (flattened, length 2^(a+w) bits).
    pub grad: Tensor,
    /// Top Hessian eigenvalue (0 when Hessian disabled).
    pub lambda: f64,
    /// Top Hessian eigenvector (empty when Hessian disabled).
    pub eigvec: Tensor,
    /// Power-iteration convergence history (|λ| per iteration).
    pub lambda_history: Vec<f64>,
}

/// Full estimation state for one model.
pub struct Estimator {
    pub layers: Vec<LayerEstimate>,
    /// Mean loss of the exact-multiplier model on the estimation batches.
    pub base_loss: f64,
}

impl Estimator {
    /// Run the estimation phase: one averaged `grad_e` pass, then (for
    /// [`HessianMode::Rank1`]) power iterations per layer.
    ///
    /// The session's current E selection is temporarily cleared: the Taylor
    /// expansion is taken around the exact model (Eq. 9's `e^(k,exact)`).
    pub fn compute(session: &mut Session, est_batches: usize, mode: HessianMode)
                   -> Result<Estimator> {
        let hessian_iters = match mode {
            HessianMode::Rank1 { iters } => iters,
            _ => 0,
        };
        let saved = session.e_list.clone();
        session.clear_selection();
        let result = Self::compute_inner(session, est_batches, hessian_iters);
        session.e_list = saved;
        result
    }

    fn compute_inner(session: &Session, est_batches: usize, hessian_iters: usize)
                     -> Result<Estimator> {
        if est_batches == 0 {
            bail!("est_batches must be ≥ 1");
        }
        let (base_loss, grads) = session.grad_e(est_batches)?;
        let mut layers: Vec<LayerEstimate> = grads
            .into_iter()
            .map(|grad| LayerEstimate {
                grad,
                lambda: 0.0,
                eigvec: Tensor::zeros(&[0]),
                lambda_history: Vec::new(),
            })
            .collect();

        if hessian_iters > 0 {
            // Per-layer power iterations are independent (each isolates its
            // diagonal Hessian block), so they run in parallel; results are
            // reassembled in layer order — bit-identical to serial.
            let dims: Vec<usize> = layers.iter().map(|l| l.grad.len()).collect();
            let results = par::try_par_map(
                &dims,
                session.jobs,
                |k, &dim| -> Result<(f64, Tensor, Vec<f64>)> {
                    // deterministic start vector (seeded by layer index)
                    let mut rng = crate::rng::Pcg::seeded(0x11e55 + k as u64);
                    let mut v = Tensor::new(
                        vec![dim],
                        (0..dim).map(|_| rng.normal() as f32).collect(),
                    )?;
                    normalize(&mut v);
                    let mut lambda = 0.0f64;
                    let mut history = Vec::with_capacity(hessian_iters);
                    for it in 0..hessian_iters {
                        // zero r in all other layers isolates the diagonal block
                        let rvecs: Vec<Tensor> = dims
                            .iter()
                            .enumerate()
                            .map(|(j, &dj)| {
                                if j == k {
                                    v.clone()
                                } else {
                                    Tensor::zeros(&[dj])
                                }
                            })
                            .collect();
                        let hr = session.hvp_e(&rvecs, it as u64 % 2)?;
                        let hv = hr[k].clone();
                        lambda = v.dot(&hv)?;
                        history.push(lambda);
                        let norm = hv.norm();
                        if norm < 1e-12 {
                            lambda = 0.0;
                            break;
                        }
                        v = hv;
                        normalize(&mut v);
                    }
                    // PSD Gauss–Newton: clamp noise
                    Ok((lambda.max(0.0), v, history))
                },
            )?;
            for (layer, (lambda, eigvec, history)) in layers.iter_mut().zip(results) {
                layer.lambda = lambda;
                layer.eigvec = eigvec;
                layer.lambda_history = history;
            }
        }

        Ok(Estimator { layers, base_loss })
    }

    /// Ω(k, AM): the Taylor estimate of Eq. 9 for one candidate — two
    /// fused integer-domain LUT dots ([`AppMul::err_dot`]): the error
    /// operand is generated from the packed LUT index, never materialized
    /// as an f32 tensor, and the result is bit-identical to the float
    /// `error_slice()` formulation it replaced. `err_dot` is an f64
    /// ascending-index chain, so the global
    /// [`crate::kernel::KernelMode`] leaves it bit-exact in `Exact` and
    /// `Wide`; only the opt-in `Fast` mode lane-stripes it, and the Ω
    /// table fingerprints are insensitive to that choice by design (the
    /// differential suite pins the `Fast` bound instead).
    pub fn perturbation(&self, layer: usize, am: &AppMul) -> Result<f64> {
        let le = &self.layers[layer];
        let e_len = am.lut.len();
        if e_len != le.grad.len() {
            bail!(
                "layer {layer}: AppMul {} has E length {}, expected {}",
                am.name,
                e_len,
                le.grad.len()
            );
        }
        let first = am.err_dot(le.grad.data())?;
        let second = if le.lambda > 0.0 && le.eigvec.len() == e_len {
            let proj = am.err_dot(le.eigvec.data())?;
            0.5 * le.lambda * proj * proj
        } else {
            0.0
        };
        Ok(first + second)
    }

    /// Exact Gauss–Newton quadratic for one candidate on one layer:
    /// `½ e·(H_kk e)` from a single HVP with e isolated in layer `k`.
    pub fn quadratic_exact(session: &Session, layer: usize, e: &Tensor) -> Result<f64> {
        let n = session.art.manifest.layers.len();
        let rvecs: Vec<Tensor> = (0..n)
            .map(|j| {
                if j == layer {
                    e.clone()
                } else {
                    Tensor::zeros(&[session.art.manifest.layers[j].e_len()])
                }
            })
            .collect();
        let hr = session.hvp_e(&rvecs, 0)?;
        Ok(0.5 * e.dot(&hr[layer])?)
    }

    /// Fig. 5(c) baseline estimator: L2 norm of the error matrix.
    pub fn l2_estimate(am: &AppMul) -> f64 {
        am.metrics.e_l2
    }

    /// Fig. 5(c) baseline estimator: MRED of the AppMul.
    pub fn mre_estimate(am: &AppMul) -> f64 {
        am.metrics.mred
    }
}

/// Precomputed Ω table aligned with `library.for_bits(...)` ordering per
/// layer — what the ILP consumes. Built once per model; candidate lookup is
/// then O(1) (the paper's "compute once" speed-up).
#[derive(Clone, Debug)]
pub struct PerturbTable {
    /// `values[layer][choice]` = Ω(layer, choice).
    pub values: Vec<Vec<f64>>,
    /// AppMul name per entry (diagnostics / reports).
    pub names: Vec<Vec<String>>,
    pub base_loss: f64,
    /// Wall-clock spent estimating (Table II "Select Time" component).
    pub estimate_secs: f64,
}

/// Build the full Ω table for a session + library under a Hessian mode.
pub fn estimate_table(
    session: &mut Session,
    library: &Library,
    est_batches: usize,
    mode: HessianMode,
) -> Result<(Estimator, PerturbTable)> {
    let t0 = std::time::Instant::now();
    let est = Estimator::compute(session, est_batches, mode)?;
    let saved = session.e_list.clone();
    session.clear_selection();
    let jobs = session.jobs;
    let sref: &Session = session;
    let per_layer_muls: Vec<Vec<&crate::appmul::AppMul>> = sref
        .art
        .manifest
        .layers
        .iter()
        .map(|l| library.for_bits(l.a_bits, l.w_bits))
        .collect();
    // first-order terms (two dot products each), one parallel unit per layer
    let rows = par::try_par_map(
        &per_layer_muls,
        jobs,
        |k, muls| -> Result<(Vec<f64>, Vec<String>)> {
            let mut row = Vec::with_capacity(muls.len());
            let mut row_names = Vec::with_capacity(muls.len());
            for am in muls {
                // Clamp at zero: the Gauss–Newton Hessian is PSD and the model
                // is converged (∂L/∂z ≈ 0, paper §IV-C2), so a genuinely
                // negative Ω is below the estimation noise floor — leaving it
                // negative lets the ILP treat approximation as a free lunch.
                row.push(est.perturbation(k, am)?.max(0.0));
                row_names.push(am.name.clone());
            }
            Ok((row, row_names))
        },
    )?;
    let mut values = Vec::with_capacity(rows.len());
    let mut names = Vec::with_capacity(rows.len());
    for (row, row_names) in rows {
        values.push(row);
        names.push(row_names);
    }
    // exact Gauss–Newton quadratics, batched: candidate slot `i` of every
    // layer is probed in one `quad_e` execution (primal pass shared), and
    // the independent slots run concurrently.
    if mode == HessianMode::Exact {
        let use_quad = sref.has_quad_e();
        let max_c = per_layer_muls.iter().map(|m| m.len()).max().unwrap_or(0);
        let slots: Vec<usize> = (0..max_c).collect();
        let adds = par::try_par_map(&slots, jobs, |_, &i| -> Result<Vec<Option<f64>>> {
            if use_quad {
                let rvecs: Vec<Tensor> = per_layer_muls
                    .iter()
                    .enumerate()
                    .map(|(k, muls)| match muls.get(i) {
                        Some(am) if !am.is_exact() => am.error_tensor(),
                        _ => Tensor::zeros(&[sref.art.manifest.layers[k].e_len()]),
                    })
                    .collect();
                let quads = sref.quad_e(&rvecs, 0)?;
                Ok(per_layer_muls
                    .iter()
                    .enumerate()
                    .map(|(k, muls)| match muls.get(i) {
                        Some(am) if !am.is_exact() => Some(quads[k].max(0.0)),
                        _ => None,
                    })
                    .collect())
            } else {
                // fallback for artifact sets without quad_e: per-layer HVPs
                let mut adds: Vec<Option<f64>> = vec![None; per_layer_muls.len()];
                for (k, muls) in per_layer_muls.iter().enumerate() {
                    if let Some(am) = muls.get(i) {
                        if !am.is_exact() {
                            let e = am.error_tensor();
                            adds[k] =
                                Some(Estimator::quadratic_exact(sref, k, &e)?.max(0.0));
                        }
                    }
                }
                Ok(adds)
            }
        })?;
        for (i, slot_adds) in adds.into_iter().enumerate() {
            for (k, add) in slot_adds.into_iter().enumerate() {
                if let Some(add) = add {
                    values[k][i] += add;
                }
            }
        }
    }
    session.e_list = saved;
    let table = PerturbTable {
        values,
        names,
        base_loss: est.base_loss,
        estimate_secs: t0.elapsed().as_secs_f64(),
    };
    Ok((est, table))
}

fn normalize(v: &mut Tensor) {
    let n = v.norm() as f32;
    if n > 0.0 {
        v.scale(1.0 / n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::appmul::generate_library;

    #[test]
    fn perturbation_is_two_dot_products() {
        // synthetic estimator — no runtime needed
        let lib = generate_library(&[(2, 2)], 0);
        let am = lib.for_bits(2, 2)[1]; // some approximate design
        let grad = Tensor::new(vec![16], (0..16).map(|i| i as f32 * 0.1).collect()).unwrap();
        let mut eig = Tensor::full(&[16], 0.25);
        eig.data_mut()[0] = 0.5;
        let est = Estimator {
            layers: vec![LayerEstimate {
                grad: grad.clone(),
                lambda: 2.0,
                eigvec: eig.clone(),
                lambda_history: vec![2.0],
            }],
            base_loss: 1.0,
        };
        let e = am.error_tensor();
        let want = grad.dot(&e).unwrap()
            + 0.5 * 2.0 * eig.dot(&e).unwrap() * eig.dot(&e).unwrap();
        let got = est.perturbation(0, am).unwrap();
        assert!((got - want).abs() < 1e-9);
    }

    #[test]
    fn exact_multiplier_has_zero_perturbation() {
        let lib = generate_library(&[(3, 3)], 0);
        let exact = lib.exact(3, 3).unwrap();
        let est = Estimator {
            layers: vec![LayerEstimate {
                grad: Tensor::full(&[64], 1.0),
                lambda: 1.0,
                eigvec: Tensor::full(&[64], 0.125),
                lambda_history: vec![],
            }],
            base_loss: 0.0,
        };
        assert_eq!(est.perturbation(0, exact).unwrap(), 0.0);
    }

    #[test]
    fn size_mismatch_is_error() {
        let lib = generate_library(&[(3, 3)], 0);
        let am = lib.exact(3, 3).unwrap();
        let est = Estimator {
            layers: vec![LayerEstimate {
                grad: Tensor::zeros(&[16]), // wrong: 2-bit length
                lambda: 0.0,
                eigvec: Tensor::zeros(&[0]),
                lambda_history: vec![],
            }],
            base_loss: 0.0,
        };
        assert!(est.perturbation(0, am).is_err());
    }
}
