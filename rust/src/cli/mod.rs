//! Hand-rolled CLI (no `clap` in the offline crate set).
//!
//! ```text
//! fames <command> [key=value ...]
//!
//!   pipeline    run the full FAMES flow (estimate → ILP → calibrate → eval)
//!   train       fp32 pre-train a model and cache its parameters
//!   evaluate    evaluate the quantized-exact model (E = 0)
//!   library     generate + print the AppMul library for given bitwidths
//!   bits        HAWQ-like mixed-precision bitwidth proposal
//!   bench       serial-vs-parallel + cold-vs-warm perf snapshot
//!               (`--json` for machines, `--compare` to diff snapshots)
//!   cache       artifact-store maintenance (ls | stat | gc)
//!   sweep       precompute the Pareto front of selections over a budget grid
//!   serve       long-running batched evaluation daemon (NDJSON over TCP)
//!   experiment  reproduce a paper table/figure (table2|table3|table4|
//!               fig2|fig3|fig4|fig5ab|fig5c|all)
//!   help        this text
//! ```

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::appmul::generate_library;
use crate::config;
use crate::pipeline::{self, FamesConfig, Session};
use crate::report::{f3, pct, Table};
use crate::util::par;

const HELP: &str = "fames — FAMES reproduction (approximate-multiplier substitution)

USAGE: fames <command> [key=value ...]

COMMANDS
  pipeline     full flow: estimate → ILP select → calibrate → evaluate
               (stage outputs are cached content-addressed; a warm run
               loads every unchanged stage and is bit-identical)
  train        fp32 pre-train and cache parameters (steps=, train_lr=)
  evaluate     evaluate the quantized-exact model (E = 0)
  synth        write a synthetic artifact set for the native backend
               (model=resnet8 cfg=w4a4 out=artifacts)
  library      print the AppMul library (bits=4 or bits=4x8)
  bits         HAWQ-like mixed-precision proposal (budget=0.1 vs 8-bit)
  bench        serial-vs-parallel + cold-vs-warm perf snapshot per stage;
               timings are median-of-N with recorded dispersion, kernels
               also report GB/s and mults/s under a nominal work model
               (--json machine-readable, --quick smoke sizes, out=PATH,
                mode=exact|wide|fast kernel dispatch for this run,
                --compare=OLD.json [vs=NEW.json] to diff snapshots; the
                regression verdict widens with each stage's recorded
                dispersion, so honest medians work as baselines)
  cache        artifact-store maintenance: cache ls | stat | gc
               (honors artifacts=, --cache-dir; ls kind=NAME filters to
                one artifact kind; gc removes every entry)
  sweep        precompute + store the Pareto front of selections over an
               r_energy grid (pareto=0.5,0.6,0.7 plus the common keys; the
               front is one store artifact, replicated like any other, so
               warm daemons answer in-front reconfigures as cache hits)
  serve        long-running evaluation daemon: newline-delimited JSON over
               TCP (ops: evaluate | energy | select | reconfigure |
               artifact_get | artifact_put | health | status | shutdown)
               plus an optional HTTP/1.1 gateway onto the same engine
               (addr=127.0.0.1:4271  http=127.0.0.1:8471
                models=<model>/<cfg>[,...]  max_batch=16
                max_conns=1024  max_pending=4096  max_line=1048576
                write_timeout_ms=10000  --http-log, plus the common keys
                below; concurrent requests are batched into parallel
                waves and answers are bit-identical to direct Session
                calls at every jobs=; over capacity the daemon sheds
                explicitly — \"shed\":true lines / HTTP 503 + Retry-After;
                with pareto=GRID the daemon precomputes the selection
                front at warm-up and serves an active operating point
                whose fingerprint tags every evaluate response; a
                reconfigure delta over r_energy/calib knobs re-runs only
                select+calibrate and hot-swaps between waves)
               router mode: route=host:port[,...] turns the process into
               a consistent-hash router over those shard daemons — one
               NDJSON + HTTP endpoint, requests forwarded by <model>/<cfg>
               with per-shard connection pools (pool=16), liveness-driven
               membership (a prober dials each shard's health op; one
               missed probe = suspect, two = down and ejected until
               probes recover), failover to ring successors, request
               hedging against the first warm successor when the owner's
               p99 looks slow, and end-to-end shed semantics
               (connect_timeout_ms=500 io_timeout_ms=10000
                down_cooldown_ms=500 probe_interval_ms=500
                hedge_threshold=3.0, <=0 disables hedging)
  experiment   table2 | table3 | table4 | fig2 | fig3 | fig4 | fig5ab |
               fig5c | all   (writes results/<id>.csv)
  help         this text

COMMON KEYS
  model=resnet8|resnet14|resnet20|vgg11|squeezenet   cfg=w8a8|w4a4|w3a3|w2a2|mixed
  artifacts=PATH  seed=N  r_energy=0.7  est_batches=2  hessian=exact|rank1|off
  pareto=R1,R2,...  r_energy grid for the precomputed selection front
                    (sweep command and adaptive serve; sorted + deduped)
  eval_batches=4  train_steps=500  train_lr=0.05
  calib_epochs=3  calib_samples=256  calib_lr=0.1  q_step=0.02  q_max=0.3
  jobs=N (or --jobs=N)   worker threads for the parallel stages
                         (0 = auto-detect; outputs are identical either way)
  --cache-dir=PATH       artifact-store location (default artifacts/cache)
  --no-cache             disable the artifact store (recompute everything)
  peers=host:port[,...]  fleet peers consulted by the store's remote
                         read-through tier on local misses (warm handoff:
                         a fresh shard pulls calibrated artifacts and
                         trained parameters instead of recomputing)
  replication=N          copies per completed stage artifact: one local
                         plus N-1 pushed to its ring successors among
                         peers= (default 1 = local-only; push-based
                         warming keeps failover shards warm up front)

ENVIRONMENT
  FAMES_BACKEND=native|pjrt   execution backend (default native; pjrt needs
                              a build with --features pjrt plus real XLA)
  FAMES_ARTIFACTS=PATH        artifact root override
  FAMES_JOBS=N                worker-thread default when jobs= is not given
  FAMES_KERNEL_MODE=exact|wide|fast
                              kernel dispatch mode (default wide; exact and
                              wide are bit-identical, fast is opt-in and
                              verified against the exact twin in tests)
  FAMES_FAULT=SPEC            opt-in deterministic fault injection on a
                              serve daemon (chaos drills; never set in
                              production). SPEC keys, ';'- or ','-joined:
                              seed=N delay_ms=N delay_every=N drop_every=N
                              truncate_every=N refuse_every=N kill_after=N
";

/// Run the CLI. Returns a process exit code.
pub fn run(args: &[String]) -> Result<i32> {
    let cmd = args.get(1).map(|s| s.as_str()).unwrap_or("help");
    let rest = &args[2.min(args.len())..];
    match cmd {
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(0)
        }
        "pipeline" => cmd_pipeline(rest),
        "train" => cmd_train(rest),
        "evaluate" => cmd_evaluate(rest),
        "synth" => cmd_synth(rest),
        "library" => cmd_library(rest),
        "bits" => cmd_bits(rest),
        "bench" => cmd_bench(rest),
        "cache" => cmd_cache(rest),
        "sweep" => cmd_sweep(rest),
        "serve" => cmd_serve(rest),
        "experiment" => crate::experiments::run_cli(rest),
        other => {
            eprintln!("unknown command '{other}'\n\n{HELP}");
            Ok(2)
        }
    }
}

fn base_config(args: &[String]) -> Result<FamesConfig> {
    let mut cfg = FamesConfig {
        artifact_root: pipeline::artifacts_root(),
        ..FamesConfig::default()
    };
    config::apply_args(&mut cfg, args)?;
    // make the knob reach code that resolves jobs lazily (e.g. the native
    // backend's batched loops, library generation)
    if cfg.jobs > 0 {
        par::set_global_jobs(cfg.jobs);
    }
    Ok(cfg)
}

fn cmd_pipeline(args: &[String]) -> Result<i32> {
    let cfg = base_config(args)?;
    let rt = Arc::new(crate::runtime::Runtime::from_env()?);
    println!("== FAMES pipeline: {} / {} (R_energy = {}) ==", cfg.model, cfg.cfg, cfg.r_energy);
    if !cfg.no_cache {
        println!("  artifact store: {}", cfg.effective_cache_dir());
    }
    let rep = pipeline::run_cached(rt, &cfg)?;

    let mut st = Table::new("stages", &["stage", "fingerprint", "cache", "secs"]);
    for s in &rep.stages {
        st.row(vec![
            s.stage.to_string(),
            s.fingerprint.clone(),
            s.status().to_string(),
            f3(s.secs),
        ]);
    }
    st.print();

    let mut t = Table::new("result", &["metric", "value"]);
    t.row(vec!["quantized-exact accuracy (%)".into(), pct(rep.quant_eval.accuracy)]);
    t.row(vec!["approx accuracy before calib (%)".into(), pct(rep.approx_eval_before.accuracy)]);
    t.row(vec!["approx accuracy after calib (%)".into(), pct(rep.approx_eval_after.accuracy)]);
    t.row(vec!["energy vs exact same-bitwidth".into(), f3(rep.energy_ratio_exact)]);
    t.row(vec!["energy vs 8-bit baseline".into(), f3(rep.energy_ratio_8bit)]);
    t.row(vec!["quant energy vs 8-bit baseline".into(), f3(rep.quant_energy_ratio_8bit)]);
    t.row(vec!["estimate time (s)".into(), f3(rep.times.estimate_secs)]);
    t.row(vec!["select time (s)".into(), f3(rep.times.select_secs)]);
    t.row(vec!["calibrate time (s)".into(), f3(rep.times.calibrate_secs)]);
    t.row(vec!["ILP nodes".into(), rep.ilp_nodes.to_string()]);
    t.print();
    println!("selection:");
    for (l, (name, p)) in rep.selection.iter().zip(&rep.perturbations).enumerate() {
        println!("  layer {l:2}: {name}  (Ω = {p:+.5})");
    }
    Ok(0)
}

fn cmd_train(args: &[String]) -> Result<i32> {
    let cfg = base_config(args)?;
    let rt = Arc::new(crate::runtime::Runtime::from_env()?);
    let mut session = Session::open(rt, &cfg.artifact_root, &cfg.model, &cfg.cfg, cfg.seed)?;
    let curve = crate::train::train(&mut session, cfg.train_steps, cfg.train_lr)?;
    let (head, tail) = curve.head_tail(20);
    println!("trained {} steps: loss {head:.3} → {tail:.3}", cfg.train_steps);
    let path = Session::state_path(&cfg.artifact_root, &cfg.model);
    session.save_params(&path)?;
    println!("saved params to {}", path.display());
    Ok(0)
}

fn cmd_evaluate(args: &[String]) -> Result<i32> {
    let cfg = base_config(args)?;
    let rt = Arc::new(crate::runtime::Runtime::from_env()?);
    let mut session = Session::open(rt, &cfg.artifact_root, &cfg.model, &cfg.cfg, cfg.seed)?;
    pipeline::ensure_trained(&mut session, &cfg)?;
    session.init_act_ranges()?;
    let rf = session.evaluate_float(cfg.eval_batches)?;
    let r = session.evaluate(cfg.eval_batches)?;
    println!(
        "{} / {}: fp32 accuracy {} %, quantized-exact accuracy {} % (loss {:.4}, {} samples)",
        cfg.model,
        cfg.cfg,
        pct(rf.accuracy),
        pct(r.accuracy),
        r.loss,
        r.samples
    );
    Ok(0)
}

fn cmd_synth(args: &[String]) -> Result<i32> {
    use crate::runtime::backend::native::{write_synthetic_artifacts, SyntheticSpec};
    let mut model = "resnet8".to_string();
    let mut cfg = "w4a4".to_string();
    let mut out = "artifacts".to_string();
    for a in args {
        match a.split_once('=') {
            Some(("model", v)) => model = v.to_string(),
            Some(("cfg", v)) => cfg = v.to_string(),
            Some(("out", v)) => out = v.to_string(),
            _ => bail!("synth takes model=, cfg= and out= (got '{a}')"),
        }
    }
    let dir = write_synthetic_artifacts(&out, &SyntheticSpec::small(&model, &cfg))?;
    println!("wrote synthetic artifact set {}", dir.display());
    println!("try: fames pipeline model={model} cfg={cfg} artifacts={out}");
    Ok(0)
}

fn cmd_library(args: &[String]) -> Result<i32> {
    let mut bits_arg = "4".to_string();
    let mut seed = 0u64;
    for a in args {
        match a.split_once('=') {
            Some(("bits", v)) => bits_arg = v.to_string(),
            Some(("seed", v)) => seed = v.parse().context("seed")?,
            _ => bail!("library takes bits= and seed= (got '{a}')"),
        }
    }
    let (a_bits, w_bits) = match bits_arg.split_once('x') {
        Some((a, w)) => (a.parse()?, w.parse()?),
        None => {
            let b: u32 = bits_arg.parse()?;
            (b, b)
        }
    };
    let lib = generate_library(&[(a_bits, w_bits)], seed);
    let mut t = Table::new(
        format!("AppMul library {a_bits}x{w_bits} (seed {seed})"),
        &[
            "name", "family", "pdp", "energy_fj", "delay_ps", "area_um2", "gates", "mred", "er",
            "wce", "err_mean",
        ],
    );
    for m in lib.for_bits(a_bits, w_bits) {
        t.row(vec![
            m.name.clone(),
            m.family.clone(),
            f3(m.pdp),
            f3(m.energy_fj),
            format!("{:.0}", m.delay_ps),
            format!("{:.1}", m.area_um2),
            m.gates.to_string(),
            format!("{:.4}", m.metrics.mred),
            format!("{:.3}", m.metrics.er),
            m.metrics.wce.to_string(),
            // signed error direction: + overshoots, − undershoots (the
            // positive/negative pairing signal)
            format!("{:+.4}", m.err_mean()),
        ]);
    }
    t.print();
    println!("pareto frontier: {:?}",
             lib.pareto(a_bits, w_bits).iter().map(|m| m.name.as_str()).collect::<Vec<_>>());
    Ok(0)
}

fn cmd_bench(args: &[String]) -> Result<i32> {
    let mut bcfg = crate::bench::BenchConfig::default();
    let mut json = false;
    let mut out: Option<String> = None;
    let mut compare: Option<String> = None;
    let mut vs: Option<String> = None;
    for a in args {
        match a.as_str() {
            "--json" | "json=1" => json = true,
            "--quick" | "quick=1" => bcfg.quick = true,
            _ => match a.strip_prefix("--").unwrap_or(a.as_str()).split_once('=') {
                Some(("jobs", v)) => bcfg.jobs = v.parse().context("jobs")?,
                Some(("out", v)) => out = Some(v.to_string()),
                Some(("compare", v)) => compare = Some(v.to_string()),
                Some(("vs", v)) => vs = Some(v.to_string()),
                Some(("mode", v)) => {
                    let mode = crate::kernel::KernelMode::parse(v)
                        .with_context(|| format!("mode '{v}' (expected exact|wide|fast)"))?;
                    crate::kernel::set_kernel_mode(mode);
                }
                _ => bail!(
                    "bench takes --json, --quick, jobs=N, out=PATH, \
                     mode=exact|wide|fast, --compare=OLD.json, vs=NEW.json (got '{a}')"
                ),
            },
        }
    }

    // --compare: diff two snapshots. With vs=NEW.json both sides come from
    // disk; otherwise the bench runs now and the fresh snapshot is "new"
    // (and out=PATH still records it, so one run both diffs and logs the
    // new trajectory point).
    if let Some(old_path) = &compare {
        let old = crate::json::Json::load(old_path)?;
        let new = match &vs {
            Some(p) => crate::json::Json::load(p)?,
            None => {
                let stages = crate::bench::run_stages(&bcfg)?;
                crate::bench::snapshot_json(&stages, &bcfg)
            }
        };
        if let Some(path) = &out {
            new.save(path)?;
            println!("wrote {path}");
        }
        let deltas = crate::bench::compare_snapshots(&old, &new)?;
        let regressions = deltas.iter().filter(|d| d.is_regression()).count();
        if json {
            let mut arr = crate::json::Json::arr();
            for d in &deltas {
                arr.push(
                    crate::json::Json::obj()
                        .with("name", d.name.as_str())
                        .with("old_secs", d.old_secs)
                        .with("new_secs", d.new_secs)
                        .with("speedup", d.speedup())
                        .with("verdict", d.verdict()),
                );
            }
            let doc = crate::json::Json::obj()
                .with("schema", "fames-bench-compare-v1")
                .with("old", old_path.as_str())
                .with("regressions", regressions)
                .with("stages", arr);
            println!("{}", doc.pretty());
        } else {
            let new_label = vs.as_deref().unwrap_or("(fresh run)");
            let mut t = Table::new(
                format!("bench compare: {old_path} → {new_label}"),
                &["stage", "old", "new", "speedup", "verdict"],
            );
            for d in &deltas {
                t.row(vec![
                    d.name.clone(),
                    crate::util::fmt_secs(d.old_secs),
                    crate::util::fmt_secs(d.new_secs),
                    format!("{:.2}×", d.speedup()),
                    d.verdict().to_string(),
                ]);
            }
            t.print();
        }
        if regressions > 0 {
            println!("{regressions} stage(s) regressed (> {:.0}% slower)",
                     crate::bench::REGRESSION_TOLERANCE * 100.0);
            return Ok(1);
        }
        return Ok(0);
    }

    let stages = crate::bench::run_stages(&bcfg)?;
    let cache = crate::bench::run_cache_bench(&bcfg)?;
    let kernels = crate::bench::run_kernel_bench(&bcfg)?;
    let mut serve = crate::bench::run_serve_bench_full(&bcfg)?;
    serve.fleet = Some(crate::bench::run_fleet_bench(&bcfg).context("fleet bench")?);
    let doc = crate::bench::snapshot_json_full(
        &stages,
        Some(&cache),
        Some(&kernels),
        Some(&serve),
        &bcfg,
    );
    if let Some(path) = &out {
        doc.save(path)?;
        println!("wrote {path}");
    }
    if json {
        println!("{}", doc.pretty());
    } else {
        // which protocol produced each section (the JSON carries the same
        // strings under the top-level "protocol" object)
        println!(
            "protocol: stages {}; cache single-pass cold-vs-warm; kernels \
             median-of-{}; serve two-round wall-clock",
            crate::bench::stage_protocol(&stages),
            kernels.iter().map(|k| k.kernel.reps).max().unwrap_or(1),
        );
        let mut t = Table::new(
            format!(
                "fames bench (jobs = {}, kernel mode = {})",
                par::effective_jobs(bcfg.jobs),
                crate::kernel::kernel_mode().name()
            ),
            &["stage", "serial", "parallel", "speedup", "spread"],
        );
        for s in &stages {
            t.row(vec![
                s.name.to_string(),
                crate::util::fmt_secs(s.serial_secs()),
                crate::util::fmt_secs(s.parallel_secs()),
                format!("{:.2}×", s.speedup()),
                format!("{:.0}%", s.parallel.rel_spread() * 100.0),
            ]);
        }
        t.print();
        let mut ct = Table::new(
            format!(
                "pipeline cold vs warm (cache; {:.2}× end-to-end)",
                cache.speedup()
            ),
            &["stage", "cold", "warm", "cold cache", "warm cache"],
        );
        for s in &cache.stages {
            ct.row(vec![
                s.stage.to_string(),
                crate::util::fmt_secs(s.cold_secs),
                crate::util::fmt_secs(s.warm_secs),
                s.cold_status.to_string(),
                s.warm_status.to_string(),
            ]);
        }
        ct.print();
        let mut kt = Table::new(
            "per-kernel timings (fused vs reference, median-of-N)",
            &["kernel", "reference", "fused", "speedup", "GB/s", "Mmult/s", "calls"],
        );
        for k in &kernels {
            kt.row(vec![
                k.name.to_string(),
                crate::util::fmt_secs(k.reference_secs()),
                crate::util::fmt_secs(k.kernel_secs()),
                format!("{:.2}×", k.speedup()),
                format!("{:.2}", k.gb_per_sec()),
                format!("{:.1}", k.mults_per_sec() / 1e6),
                k.calls.to_string(),
            ]);
        }
        kt.print();
        let mut st = Table::new(
            format!(
                "fames serve throughput (startup {} cold / {} warm)",
                crate::util::fmt_secs(serve.startup_cold_secs),
                crate::util::fmt_secs(serve.startup_warm_secs)
            ),
            &["clients", "requests", "cold req/s", "warm req/s", "warm/cold"],
        );
        for l in &serve.levels {
            st.row(vec![
                l.clients.to_string(),
                l.requests.to_string(),
                format!("{:.1}", l.cold_rps),
                format!("{:.1}", l.warm_rps),
                format!("{:.2}×", l.speedup()),
            ]);
        }
        st.print();
        if let Some(sat) = &serve.saturation {
            let mut at = Table::new(
                format!(
                    "saturation under tiny caps (max_conns {}, max_pending {})",
                    sat.max_conns, sat.max_pending
                ),
                &["clients", "requests", "ok", "shed", "dropped", "req/s", "p50", "p99"],
            );
            for l in &sat.levels {
                at.row(vec![
                    l.clients.to_string(),
                    l.requests.to_string(),
                    l.ok.to_string(),
                    l.shed.to_string(),
                    (l.dropped + l.errors).to_string(),
                    format!("{:.1}", l.rps),
                    format!("{:.1}ms", l.p50_ms),
                    format!("{:.1}ms", l.p99_ms),
                ]);
            }
            at.print();
        }
        if let Some(r) = &serve.reconfigure {
            println!(
                "  live reconfigure ({} front points): in-front swap {} ({}) \
                 vs off-front {} ({})",
                r.front_points,
                crate::util::fmt_secs(r.warm_swap_secs),
                r.warm_source,
                crate::util::fmt_secs(r.cold_swap_secs),
                r.cold_source
            );
        }
        if let Some(f) = &serve.fleet {
            let mut ft = Table::new(
                format!(
                    "sharded fleet ({} keys; router p50 {:.1}ms vs direct {:.1}ms; \
                     spin-up cold {} / handoff {})",
                    f.keys,
                    f.router_p50_ms,
                    f.direct_p50_ms,
                    crate::util::fmt_secs(f.spinup_cold_secs),
                    crate::util::fmt_secs(f.spinup_handoff_secs)
                ),
                &["shards", "requests", "ok", "shed", "req/s", "vs single"],
            );
            for l in &f.levels {
                ft.row(vec![
                    l.shards.to_string(),
                    l.requests.to_string(),
                    l.ok.to_string(),
                    l.shed.to_string(),
                    format!("{:.1}", l.rps),
                    format!(
                        "{:.2}×",
                        if f.single_rps > 0.0 { l.rps / f.single_rps } else { 0.0 }
                    ),
                ]);
            }
            ft.print();
            if let Some(r) = &f.rolling_restart {
                println!(
                    "  rolling restart: {:.1} → {:.1} req/s during the outage \
                     ({} ok / {} shed / {} lost of {}); re-entry {} ({})",
                    r.steady_rps,
                    r.outage_rps,
                    r.outage_ok,
                    r.outage_shed,
                    r.lost,
                    r.outage_requests,
                    crate::util::fmt_secs(r.reentry_secs),
                    if r.warm_reentry { "warm from replicas" } else { "RETRAINED" }
                );
            }
            if let Some(h) = &f.hedged_p99 {
                println!(
                    "  hedged tail (+{}ms on the owner): p99 {:.1}ms → {:.1}ms \
                     ({} hedged, {} wins)",
                    h.slow_delay_ms, h.unhedged_p99_ms, h.hedged_p99_ms, h.hedged, h.hedge_wins
                );
            }
        }
    }
    Ok(0)
}

fn cmd_sweep(args: &[String]) -> Result<i32> {
    let cfg = base_config(args)?;
    anyhow::ensure!(
        !cfg.pareto_grid.is_empty(),
        "sweep needs a budget grid: pareto=0.5,0.6,0.7[,...]"
    );
    let rt = Arc::new(crate::runtime::Runtime::from_env()?);
    println!(
        "== FAMES sweep: {} / {} over {} budgets ==",
        cfg.model,
        cfg.cfg,
        cfg.pareto_grid.len()
    );
    if !cfg.no_cache {
        println!("  artifact store: {}", cfg.effective_cache_dir());
    }
    let mut session = pipeline::warm_session(rt, &cfg)?;
    let store = cfg.store();
    let prep =
        pipeline::prepare_library(&session.art.manifest, cfg.seed, store.as_ref(), cfg.jobs)?;
    let sweep = pipeline::active::sweep_pareto(&mut session, &prep.library, prep.fingerprint, &cfg)?;
    let cache = match sweep.hit {
        Some(true) => "hit",
        Some(false) => "miss",
        None => "off",
    };
    let mut t = Table::new(
        format!("pareto front {} ({cache}, {} s)", sweep.fingerprint.hex(), f3(sweep.secs)),
        &["r_energy", "selection", "energy vs exact", "picks"],
    );
    for p in &sweep.front.points {
        t.row(vec![
            format!("{}", p.r_energy),
            p.fingerprint.hex(),
            f3(p.energy_ratio_exact),
            p.names.join(","),
        ]);
    }
    t.print();
    println!(
        "reconfigure to any budget above is a cache hit + swap on a warm \
         daemon (POST /v1/reconfigure {{\"delta\":{{\"r_energy\":R}}}})"
    );
    Ok(0)
}

fn cmd_serve(args: &[String]) -> Result<i32> {
    let defaults = crate::serve::ServeConfig::default();
    let mut addr = defaults.addr.clone();
    let mut http_addr: Option<String> = None;
    let mut models: Option<Vec<String>> = None;
    let mut max_batch = defaults.max_batch;
    let mut max_conns = defaults.max_conns;
    let mut max_pending = defaults.max_pending;
    let mut max_line = defaults.max_line;
    let mut write_timeout_ms = defaults.write_timeout_ms;
    let mut access_log = false;
    let router_defaults = crate::serve::RouterConfig::default();
    let mut route: Option<Vec<String>> = None;
    let mut pool_per_shard = router_defaults.pool_per_shard;
    let mut connect_timeout_ms = router_defaults.connect_timeout_ms;
    let mut io_timeout_ms = router_defaults.io_timeout_ms;
    let mut down_cooldown_ms = router_defaults.down_cooldown_ms;
    let mut probe_interval_ms = router_defaults.probe_interval_ms;
    let mut hedge_threshold = router_defaults.hedge_threshold;
    let mut kv = Vec::new();
    for a in args {
        if a == "--http-log" || a == "http_log" {
            access_log = true;
            continue;
        }
        match a.strip_prefix("--").unwrap_or(a.as_str()).split_once('=') {
            Some(("addr", v)) => addr = v.to_string(),
            Some(("http", v)) => http_addr = Some(v.to_string()),
            Some(("route", v)) => {
                route = Some(
                    v.split(',')
                        .map(str::trim)
                        .filter(|s| !s.is_empty())
                        .map(str::to_string)
                        .collect(),
                )
            }
            Some(("pool", v)) => pool_per_shard = v.parse().context("pool")?,
            Some(("connect_timeout_ms", v)) | Some(("connect-timeout-ms", v)) => {
                connect_timeout_ms = v.parse().context("connect_timeout_ms")?
            }
            Some(("io_timeout_ms", v)) | Some(("io-timeout-ms", v)) => {
                io_timeout_ms = v.parse().context("io_timeout_ms")?
            }
            Some(("down_cooldown_ms", v)) | Some(("down-cooldown-ms", v)) => {
                down_cooldown_ms = v.parse().context("down_cooldown_ms")?
            }
            Some(("probe_interval_ms", v)) | Some(("probe-interval-ms", v)) => {
                probe_interval_ms = v.parse().context("probe_interval_ms")?
            }
            Some(("hedge_threshold", v)) | Some(("hedge-threshold", v)) => {
                hedge_threshold = v.parse().context("hedge_threshold")?
            }
            Some(("models", v)) => {
                models = Some(v.split(',').map(|s| s.trim().to_string()).collect())
            }
            Some(("max_batch", v)) | Some(("max-batch", v)) => {
                max_batch = v.parse().context("max_batch")?
            }
            Some(("max_conns", v)) | Some(("max-conns", v)) => {
                max_conns = v.parse().context("max_conns")?
            }
            Some(("max_pending", v)) | Some(("max-pending", v)) => {
                max_pending = v.parse().context("max_pending")?
            }
            Some(("max_line", v)) | Some(("max-line", v)) => {
                max_line = v.parse().context("max_line")?
            }
            Some(("write_timeout_ms", v)) | Some(("write-timeout-ms", v)) => {
                write_timeout_ms = v.parse().context("write_timeout_ms")?
            }
            Some(("http_log", v)) | Some(("http-log", v)) => access_log = v != "0",
            _ => kv.push(a.clone()),
        }
    }

    // route= turns this process into the fleet router: no models, no
    // engine — just the ring, the pools, and both front doors.
    if let Some(shards) = route {
        if !kv.is_empty() {
            bail!(
                "router mode forwards requests; model/config keys belong on \
                 the shard daemons (got '{}')",
                kv.join(" ")
            );
        }
        let rcfg = crate::serve::RouterConfig {
            addr,
            http_addr,
            shards,
            pool_per_shard,
            max_conns,
            max_line,
            write_timeout_ms,
            connect_timeout_ms,
            io_timeout_ms,
            down_cooldown_ms,
            probe_interval_ms,
            hedge_threshold,
        };
        println!("== fames serve router ({}) ==", crate::serve::PROTOCOL);
        let router = crate::serve::Router::bind(&rcfg)?;
        let mut t = Table::new(
            format!("ring ({} virtual nodes per shard)", crate::serve::ring::VNODES),
            &["index", "shard"],
        );
        for (i, s) in router.ring().shards().iter().enumerate() {
            t.row(vec![i.to_string(), s.clone()]);
        }
        t.print();
        println!(
            "routing on {} (pool {pool_per_shard}/shard, max_conns {max_conns}, \
             probe every {} ms, hedge_threshold {hedge_threshold}) — \
             send {{\"id\":0,\"op\":\"shutdown\"}} to stop the router",
            router.local_addr(),
            probe_interval_ms.max(down_cooldown_ms)
        );
        if let Some(h) = router.http_local_addr() {
            println!("http gateway on {h} (POST /v1/evaluate|energy|select|reconfigure, GET /v1/status)");
        }
        router.run()?;
        println!("fames serve router: stopped");
        return Ok(0);
    }

    let base = base_config(&kv)?;
    let models = models.unwrap_or_else(|| vec![format!("{}/{}", base.model, base.cfg)]);
    // opt-in chaos: a fault plan in the environment arms the daemon's
    // deterministic fault-injection layer (drills only)
    let fault = crate::serve::FaultPlan::from_env()?.map(std::sync::Arc::new);
    if let Some(f) = &fault {
        println!("!! fault injection armed from ${}: {f:?}", crate::serve::fault::FAULT_ENV);
    }
    let scfg = crate::serve::ServeConfig {
        addr,
        http_addr,
        models,
        max_batch,
        max_conns,
        max_pending,
        max_line,
        write_timeout_ms,
        access_log,
        base,
        fault,
    };
    println!("== fames serve ({}) ==", crate::serve::PROTOCOL);
    let server = crate::serve::Server::bind(&scfg)?;
    let mut t =
        Table::new("models", &["key", "layers", "warm (s)", "library", "params", "active", "pareto"]);
    // bind() warmed every entry; show what startup cost and whether the
    // artifact store (local or a fleet peer, for params) paid off
    let shared_addr = server.local_addr();
    {
        let reg = server.registry();
        for e in reg.entries() {
            t.row(vec![
                e.key.clone(),
                e.session.art.manifest.layers.len().to_string(),
                f3(e.warm_secs),
                match e.lib_hit {
                    Some(true) => "hit".into(),
                    Some(false) => "miss".into(),
                    None => "off".into(),
                },
                match e.params_source {
                    pipeline::ParamsSource::StateFile => "state_file".into(),
                    pipeline::ParamsSource::Store => "store".into(),
                    pipeline::ParamsSource::Trained => "trained".into(),
                },
                match e.active_fingerprint() {
                    Some(fp) => fp.hex(),
                    None => "-".into(),
                },
                match &e.pareto {
                    Some(f) => format!("{} pts", f.points.len()),
                    None => "-".into(),
                },
            ]);
        }
    }
    t.print();
    println!(
        "listening on {shared_addr} (max_batch {max_batch}, jobs {}) — send \
         {{\"id\":0,\"op\":\"shutdown\"}} to stop",
        par::effective_jobs(scfg.base.jobs)
    );
    if let Some(h) = server.http_local_addr() {
        println!("http gateway on {h} (POST /v1/evaluate|energy|select|reconfigure, GET /v1/status)");
    }
    println!(
        "admission: max_conns {max_conns}, max_pending {max_pending}, \
         max_line {max_line} B, write_timeout {write_timeout_ms} ms"
    );
    server.run()?;
    println!("fames serve: drained and stopped");
    Ok(0)
}

fn cmd_cache(args: &[String]) -> Result<i32> {
    let sub = args.first().map(String::as_str).unwrap_or("stat");
    // kind= is cache-specific, not a config key — pull it out before
    // base_config sees (and rejects) it
    let mut kind: Option<String> = None;
    let mut rest = Vec::new();
    for a in &args[1.min(args.len())..] {
        match a.strip_prefix("--").unwrap_or(a.as_str()).split_once('=') {
            Some(("kind", v)) => kind = Some(v.to_string()),
            _ => rest.push(a.clone()),
        }
    }
    if kind.is_some() && sub != "ls" {
        bail!("kind= only applies to cache ls (got cache {sub})");
    }
    let cfg = base_config(&rest)?;
    let Some(store) = cfg.store() else {
        println!("artifact store disabled (--no-cache)");
        return Ok(0);
    };
    match sub {
        "ls" => {
            let mut entries = store.entries();
            if let Some(k) = &kind {
                entries.retain(|e| &e.kind == k);
            }
            let title = match &kind {
                Some(k) => format!("cache entries ({}, kind={k})", store.root().display()),
                None => format!("cache entries ({})", store.root().display()),
            };
            let mut t = Table::new(title, &["kind", "fingerprint", "bytes"]);
            for e in &entries {
                t.row(vec![e.kind.clone(), e.fingerprint.clone(), e.bytes.to_string()]);
            }
            t.print();
            println!("{} entries", entries.len());
        }
        "stat" => {
            let stat = store.stat();
            let mut t = Table::new(
                format!("cache stat ({})", store.root().display()),
                &["kind", "entries", "bytes"],
            );
            for (kind, n, bytes) in &stat.by_kind {
                t.row(vec![kind.clone(), n.to_string(), bytes.to_string()]);
            }
            t.row(vec!["total".into(), stat.entries.to_string(), stat.total_bytes.to_string()]);
            t.print();
        }
        "gc" => {
            let (n, bytes) = store.gc()?;
            println!(
                "removed {n} entries ({bytes} bytes) from {}",
                store.root().display()
            );
        }
        other => bail!("cache takes ls | stat | gc (got '{other}')"),
    }
    Ok(0)
}

fn cmd_bits(args: &[String]) -> Result<i32> {
    let mut budget = 0.10;
    let mut kv = Vec::new();
    for a in args {
        if let Some(("budget", v)) = a.split_once('=') {
            budget = v.parse().context("budget")?;
        } else {
            kv.push(a.clone());
        }
    }
    let cfg = base_config(&kv)?;
    let rt = Arc::new(crate::runtime::Runtime::from_env()?);
    let mut session = Session::open(rt, &cfg.artifact_root, &cfg.model, &cfg.cfg, cfg.seed)?;
    pipeline::ensure_trained(&mut session, &cfg)?;
    let lib = generate_library(&[(2, 2), (3, 3), (4, 4), (8, 8)], cfg.seed);
    let alloc = crate::quant::allocate_bits(
        &session.art.manifest,
        &session.params,
        &lib,
        budget,
        &[2, 3, 4, 8],
    )?;
    println!("proposed bitwidths (avg {:.2}, energy {:.3}× of 8-bit):",
             alloc.avg_bits, alloc.energy_ratio_8bit);
    for (l, b) in session.art.manifest.layers.iter().zip(&alloc.bits) {
        println!("  {:12} {} bits", l.name, b);
    }
    Ok(0)
}
