//! fp32 pre-training driver (rust-side, via the `train` artifact).
//!
//! Thin utilities over `Session::train` used by the quickstart example and
//! the experiment drivers: loss-curve recording and simple convergence
//! checks. Python never runs here — the SGD step itself is an AOT-compiled
//! executable.

use anyhow::Result;

use crate::pipeline::Session;

/// Loss curve of one training run.
#[derive(Clone, Debug, Default)]
pub struct TrainCurve {
    pub losses: Vec<f64>,
}

impl TrainCurve {
    /// Mean loss over the first / last `k` steps (convergence summary).
    pub fn head_tail(&self, k: usize) -> (f64, f64) {
        let k = k.min(self.losses.len()).max(1);
        let head = self.losses.iter().take(k).sum::<f64>() / k as f64;
        let tail = self.losses.iter().rev().take(k).sum::<f64>() / k as f64;
        (head, tail)
    }

    /// True when the tail improves on the head by at least `factor`.
    pub fn converged(&self, factor: f64) -> bool {
        let (head, tail) = self.head_tail(20);
        tail < head / factor
    }
}

/// Train with a 2-phase lr schedule and return the loss curve.
pub fn train(session: &mut Session, steps: usize, lr: f32) -> Result<TrainCurve> {
    Ok(TrainCurve {
        losses: session.train(steps, lr)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_tail_and_convergence() {
        let c = TrainCurve {
            losses: (0..100).map(|i| 2.3 * (0.97f64).powi(i)).collect(),
        };
        let (head, tail) = c.head_tail(10);
        assert!(head > tail);
        assert!(c.converged(1.5));
        let flat = TrainCurve {
            losses: vec![2.3; 100],
        };
        assert!(!flat.converged(1.1));
    }
}
