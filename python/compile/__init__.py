"""FAMES compile path (build-time only)."""
