"""Quantization primitives (Layer 2).

Implements the paper's preliminaries:

* asymmetric uniform quantization (Eq. 1-2): ``v̂ = round((v - b) / s)``,
  ``v ≈ s·v̂ + b`` with unsigned codes in ``[0, 2^N - 1]`` so the code pair
  directly indexes the AppMul LUT;
* Learnable Weight Clipping (LWC, Eq. 6, from OmniQuant): learnable γ/β
  squeeze the clip range ``[σ(γ)·min(W), σ(β)·max(W)]``;
* straight-through estimator (STE) rounding for the calibration /
  retraining graphs.
"""

import jax
import jax.numpy as jnp


def round_ste(x):
    """Round with a straight-through gradient (identity backward)."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def sigmoid(x):
    return jax.nn.sigmoid(x)


def act_qparams_init(x_min, x_max, bits):
    """Initial activation scale/offset covering ``[x_min, x_max]``."""
    levels = (1 << bits) - 1
    span = max(x_max - x_min, 1e-6)
    return span / levels, x_min


def quantize_act(x, s, b, bits, ste=False):
    """Quantize activations to unsigned codes.

    Returns ``(codes, dequantized)``. ``codes`` are float-valued integers in
    ``[0, 2^bits - 1]`` (everything crossing PJRT is f32).
    """
    levels = (1 << bits) - 1
    rnd = round_ste if ste else jnp.round
    q = jnp.clip(rnd((x - b) / s), 0.0, float(levels))
    return q, s * q + b


def lwc_weight_quant(w, gamma, beta, bits, ste=False):
    """LWC-clipped weight quantization (paper Eq. 6 + Eq. 1-2).

    **Per-output-channel** ranges (HAWQ/OmniQuant practice): for a conv
    weight ``[O, I, kh, kw]`` the min/max reduce over all but the leading
    axis, so each output channel gets its own scale/offset. γ/β stay scalar
    per layer, exactly as in Eq. 6. Returns ``(codes, dequantized, s_w,
    b_w)`` with ``s_w``/``b_w`` broadcastable against ``w``.
    """
    if w.ndim > 1:
        axes = tuple(range(1, w.ndim))
        w_min = jnp.min(w, axis=axes, keepdims=True)
        w_max = jnp.max(w, axis=axes, keepdims=True)
    else:
        w_min = jnp.min(w)
        w_max = jnp.max(w)
    lo = sigmoid(gamma) * w_min
    hi = sigmoid(beta) * w_max
    # Guard the degenerate all-equal case.
    hi = jnp.maximum(hi, lo + 1e-6)
    w_c = jnp.clip(w, lo, hi)
    levels = (1 << bits) - 1
    s = (hi - lo) / levels
    b = lo
    rnd = round_ste if ste else jnp.round
    q = jnp.clip(rnd((w_c - b) / s), 0.0, float(levels))
    return q, s * q + b, s, b
