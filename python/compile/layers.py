"""Layer 2 — quantized/approximate conv layers.

Implements the paper's Eq. 4 (quantized conv) and Eq. 8
(``Y_approx = Y_exact + s_X·s_W · Σ_sites E[x̂, ŵ]``). The error term is
**linear in the flattened error vector e**, so JAX reverse-mode through it
yields exactly the counting-matrix-weighted gradient of Eq. 10, and
forward-over-reverse yields exact Gauss–Newton Hessian-vector products
(Eq. 11) — see DESIGN.md §4.

Two implementations of the error term:

* ``error_gemm_onehot`` — one-hot × pre-gathered-LUT GEMM (BLAS/MXU-shaped);
  used for low bitwidths, and routed through the Pallas kernel when
  ``use_pallas`` (inference artifacts only).
* ``error_gemm_gather`` — k-chunked gather; cheaper when Q is large (8-bit).

Both are differentiable in ``e`` (codes are wrapped in stop_gradient).
"""

from dataclasses import dataclass
from typing import List, Optional

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import lut_gemm as lk
from . import quant

# Cap on materialized one-hot elements per chunk (f32 count).
ONEHOT_ELEM_CAP = 1 << 24
# Above this Q, the gather formulation is cheaper than one-hot GEMM.
ONEHOT_MAX_Q = 32


@dataclass(frozen=True)
class ConvSpec:
    """Static geometry of one substitutable conv layer."""

    name: str
    in_ch: int
    out_ch: int
    kernel: int
    stride: int = 1
    pad: Optional[int] = None  # default: same-ish (kernel // 2)

    @property
    def padding(self) -> int:
        return self.kernel // 2 if self.pad is None else self.pad

    def out_hw(self, h: int, w: int):
        p, k, s = self.padding, self.kernel, self.stride
        return ((h + 2 * p - k) // s + 1, (w + 2 * p - k) // s + 1)

    def mults_per_image(self, h: int, w: int) -> int:
        ho, wo = self.out_hw(h, w)
        return self.out_ch * ho * wo * self.in_ch * self.kernel * self.kernel


@dataclass
class QContext:
    """Per-trace quantization/approximation context.

    mode: 'float' | 'quant' | 'approx'.
    ste: straight-through rounding (calibration / retraining graphs).
    use_pallas: route the error GEMM through the Pallas kernel (fwd only).
    act_q: per-layer (s_x, b_x); lwc: per-layer (gamma, beta);
    e_list: per-layer flat error vectors (length 2^(w_bits+a_bits));
    w_bits/a_bits: per-layer bitwidths.
    collect: when not None, pre-quant conv inputs are appended per layer.
    """

    mode: str = "float"
    ste: bool = False
    use_pallas: bool = False
    act_q: Optional[List] = None
    lwc: Optional[List] = None
    e_list: Optional[List] = None
    w_bits: Optional[List[int]] = None
    a_bits: Optional[List[int]] = None
    collect: Optional[List] = None


def im2col(x, kernel: int, stride: int, pad: int):
    """NCHW → ``[B, P, K]`` patch matrix (K = C·kh·kw, matching
    ``w.reshape(O, -1)`` ordering), plus the output spatial dims."""
    b, _, h, w = x.shape
    patches = lax.conv_general_dilated_patches(
        x, (kernel, kernel), (stride, stride), padding=((pad, pad), (pad, pad))
    )  # [B, C*kh*kw, Ho, Wo]
    _, k_dim, ho, wo = patches.shape
    return patches.reshape(b, k_dim, ho * wo).transpose(0, 2, 1), (ho, wo)


def error_gemm_onehot(x_codes, ew, use_pallas=False):
    """``err[b, p, o] = Σ_k EW[k, x̂[b,p,k], o]`` via one-hot GEMM.

    x_codes: [B, P, K] float codes; ew: [K, Q, O].
    Chunks the flattened (B·P) dimension so the one-hot never exceeds
    ONEHOT_ELEM_CAP elements.
    """
    b, p, k = x_codes.shape
    _, q, o = ew.shape
    m = b * p
    xm = x_codes.reshape(m, k)
    if use_pallas:
        err = lk.lut_gemm(xm, ew)
        return err.reshape(b, p, o)
    chunk = max(1, min(m, ONEHOT_ELEM_CAP // max(1, k * q)))
    ew_mat = ew.reshape(k * q, o)

    def one_chunk(xc):
        oh = jax.nn.one_hot(xc.astype(jnp.int32), q, dtype=jnp.float32)  # [mc, K, Q]
        return oh.reshape(xc.shape[0], k * q) @ ew_mat

    if chunk >= m:
        return one_chunk(xm).reshape(b, p, o)
    n_chunks = -(-m // chunk)
    m_pad = n_chunks * chunk - m
    xm = jnp.pad(xm, ((0, m_pad), (0, 0)))
    out = lax.map(one_chunk, xm.reshape(n_chunks, chunk, k))
    return out.reshape(n_chunks * chunk, o)[:m].reshape(b, p, o)


def error_gemm_gather(x_codes, w_codes, e, qw: int, k_chunk: int = 8):
    """``err[b, p, o] = Σ_k e_flat[x̂[b,p,k]·Qw + ŵ[o,k]]`` via k-chunked
    gather — cheaper than one-hot when Q is large (8-bit layers).

    x_codes: [B, P, K]; w_codes: [O, K]; e: flat [Qx·Qw].
    """
    b, p, k = x_codes.shape
    o = w_codes.shape[0]
    k_pad = (-k) % k_chunk
    if k_pad:
        # Padded slots index e[0·Qw + 0]; subtract their contribution after.
        x_codes = jnp.pad(x_codes, ((0, 0), (0, 0), (0, k_pad)))
        w_codes = jnp.pad(w_codes, ((0, 0), (0, k_pad)))
    n_steps = (k + k_pad) // k_chunk
    xs = x_codes.reshape(b, p, n_steps, k_chunk).transpose(2, 0, 1, 3)  # [S,B,P,kc]
    ws = w_codes.reshape(o, n_steps, k_chunk).transpose(1, 0, 2)  # [S,O,kc]

    def step(acc, inp):
        xc, wc = inp  # [B,P,kc], [O,kc]
        idx = (xc[:, :, None, :] * qw + wc[None, None, :, :]).astype(jnp.int32)
        return acc + jnp.take(e, idx, axis=0).sum(-1), None

    init = jnp.zeros((b, p, o), jnp.float32)
    acc, _ = lax.scan(step, init, (xs, ws))
    if k_pad:
        acc = acc - k_pad * e[0]
    return acc


def error_conv(x, spec: ConvSpec, x_codes_img, w_codes, e_flat, qx: int, qw: int,
               use_pallas: bool = False):
    """Error term of Eq. 8 for a conv layer, shaped [B, O, Ho, Wo].

    x_codes_img: [B, C, H, W] activation codes; w_codes: [O, C, kh, kw].
    """
    del x  # geometry comes from codes
    b = x_codes_img.shape[0]
    patches, (ho, wo) = im2col(x_codes_img, spec.kernel, spec.stride, spec.padding)
    w_mat = w_codes.reshape(spec.out_ch, -1)  # [O, K]
    if qx <= ONEHOT_MAX_Q and qw <= ONEHOT_MAX_Q:
        e2d = e_flat.reshape(qx, qw)
        ew = lk.build_ew(e2d, w_mat.T)  # [K, Qx, O]
        err = error_gemm_onehot(patches, ew, use_pallas=use_pallas)
    else:
        err = error_gemm_gather(patches, w_mat, e_flat, qw)
    return err.reshape(b, ho, wo, spec.out_ch).transpose(0, 3, 1, 2)


def conv_float(x, w, b, spec: ConvSpec):
    """Plain f32 conv + bias."""
    y = lax.conv_general_dilated(
        x, w, (spec.stride, spec.stride),
        padding=((spec.padding, spec.padding), (spec.padding, spec.padding)),
    )
    return y + b[None, :, None, None]


def conv_apply(i: int, spec: ConvSpec, params, ctx: QContext, x):
    """Apply conv layer `i` under the context's mode.

    In 'quant'/'approx' modes this computes Eq. 4 via dequantized operands
    (mathematically identical, numerically friendlier), and in 'approx' adds
    the Eq. 8 error term with stop-gradient codes.
    """
    w = params[f"{spec.name}.w"]
    b = params[f"{spec.name}.b"]
    if ctx.collect is not None:
        ctx.collect.append(x)
    if ctx.mode == "float":
        return conv_float(x, w, b, spec)
    s_x, b_x = ctx.act_q[i]
    gamma, beta = ctx.lwc[i]
    a_bits, w_bits = ctx.a_bits[i], ctx.w_bits[i]
    xq, x_deq = quant.quantize_act(x, s_x, b_x, a_bits, ste=ctx.ste)
    wq, w_deq, s_w, _b_w = quant.lwc_weight_quant(w, gamma, beta, w_bits, ste=ctx.ste)
    y = conv_float(x_deq, w_deq, b, spec)
    if ctx.mode == "approx":
        e_flat = ctx.e_list[i]
        x_codes = lax.stop_gradient(xq)
        w_codes = lax.stop_gradient(wq)
        err = error_conv(x, spec, x_codes, w_codes, e_flat,
                         qx=1 << a_bits, qw=1 << w_bits,
                         use_pallas=ctx.use_pallas)
        # s_w is per-output-channel [O,1,1,1]; broadcast over [B,O,Ho,Wo]
        sw_b = s_w.reshape(1, -1, 1, 1) if jnp.ndim(s_w) > 0 else s_w
        y = y + s_x * sw_b * err
    return y


def avg_pool(x, k: int = 2):
    b, c, h, w = x.shape
    return x.reshape(b, c, h // k, k, w // k, k).mean(axis=(3, 5))


def global_avg_pool(x):
    return x.mean(axis=(2, 3))


def linear(x, w, b):
    return x @ w + b


def cross_entropy(logits, labels_f32):
    """Per-sample CE; labels arrive as f32 class indices (PJRT contract)."""
    labels = labels_f32.astype(jnp.int32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
