"""AOT exporter — lowers the Layer-2 graphs to HLO **text** artifacts.

This is the compile-path half of the three-layer architecture: python/jax
authors the computation, rust loads and runs it via the PJRT C API. HLO text
(not serialized HloModuleProto) is the interchange format: jax ≥ 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 rejects; the
text parser reassigns ids, so text round-trips cleanly.

Per (model, bitwidth-config) this writes ``artifacts/<model>_<cfg>/`` with
eight executables plus ``manifest.json`` (the rust↔python contract, see
``rust/src/runtime/manifest.rs``):

  train       fp32 SGD-momentum step (rust pre-trains the baseline)
  acts_float  fp32 forward, returns each conv's input (initial act ranges)
  fwd         quantized+approx forward → loss_sum, correct, logits
  fwd_pallas  same, error GEMM routed through the Pallas kernel (Layer 1)
  fwd_acts    quantized+approx forward → per-layer conv inputs + loss
  grad_e      ∇_E loss (Eq. 10 via the gather-transpose ≡ counting matrix)
  hvp_e       Gauss–Newton Hessian-vector products in E-space (Eq. 11)
  calib       ∂loss/∂(γ, β) per layer (LWC calibration, Algorithm 1)
  retrain     grads wrt all weights/biases + γ/β (Table IV baseline)

Usage: ``python -m compile.aot --out-root ../artifacts [--sets resnet8_w4a4,...]``
"""

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import models, quant
from .layers import QContext, cross_entropy

TRAIN_BATCH = 64
EVAL_BATCH = 128
MOMENTUM = 0.9

# Default artifact build matrix: (model, cfg) pairs used by the experiment
# drivers. Kept deliberately small for w8a8 (the gather path is ~16× the
# 4-bit cost).
DEFAULT_SETS = [
    ("resnet8", "w8a8"),
    ("resnet8", "w4a4"),
    ("resnet8", "w3a3"),
    ("resnet8", "w2a2"),
    ("resnet8", "mixed"),
    ("resnet14", "w4a4"),
    ("resnet14", "mixed"),
    ("resnet20", "w8a8"),
    ("resnet20", "w4a4"),
    ("resnet20", "w3a3"),
    ("resnet20", "w2a2"),
    ("resnet20", "mixed"),
    ("vgg11", "w8a8"),
    ("vgg11", "w3a3"),
    ("squeezenet", "w8a8"),
    ("squeezenet", "w3a3"),
    ("squeezenet", "w2a2"),
]


def bit_config(md: models.ModelDef, cfg: str):
    """Per-layer (w_bits, a_bits) lists for a named config.

    ``mixed`` follows the HAWQ-style pattern the paper evaluates: the stem
    (most sensitive) keeps 8 bits, the middle of the network 4, the deepest
    third (least sensitive, most multiplications already downsampled) 2 —
    average ≈ 4.1 bits, mirroring the paper's Table III mixed rows.
    """
    n = len(md.convs)
    if cfg.startswith("w") and "a" in cfg:
        wb = int(cfg[1:cfg.index("a")])
        ab = int(cfg[cfg.index("a") + 1:])
        return [wb] * n, [ab] * n
    if cfg == "mixed":
        bits = []
        for i in range(n):
            if i == 0:
                bits.append(8)
            elif i < (2 * n) // 3:
                bits.append(4)
            else:
                bits.append(2)
        return bits, list(bits)
    raise KeyError(f"unknown config '{cfg}'")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Argument (un)packing — the order here IS the manifest contract.
# ---------------------------------------------------------------------------


class Packing:
    """Builds flat argument specs and unpackers for one artifact set."""

    def __init__(self, md: models.ModelDef, wb, ab, in_shapes):
        self.md = md
        self.wb, self.ab = wb, ab
        self.n = len(md.convs)
        self.param_shapes = [md._param_shape(n) for n in md.param_names]
        self.e_lens = [(1 << ab[i]) * (1 << wb[i]) for i in range(self.n)]
        self.in_shapes = in_shapes  # per-conv input (C, H, W)

    def specs(self, groups, batch):
        """ShapeDtypeStructs for the given ordered input groups."""
        s = []
        f32 = jnp.float32
        for g in groups:
            if g == "params":
                s += [jax.ShapeDtypeStruct(sh, f32) for sh in self.param_shapes]
            elif g == "opt_state":
                s += [jax.ShapeDtypeStruct(sh, f32) for sh in self.param_shapes]
            elif g == "lwc":
                s += [jax.ShapeDtypeStruct((), f32)] * (2 * self.n)
            elif g == "act_q":
                s += [jax.ShapeDtypeStruct((), f32)] * (2 * self.n)
            elif g in ("e_list", "rvecs"):
                s += [jax.ShapeDtypeStruct((l,), f32) for l in self.e_lens]
            elif g in ("images_train", "images_eval"):
                s.append(jax.ShapeDtypeStruct((batch, *self.md.image_shape), f32))
            elif g in ("labels_train", "labels_eval"):
                s.append(jax.ShapeDtypeStruct((batch,), f32))
            elif g == "lr":
                s.append(jax.ShapeDtypeStruct((), f32))
            else:
                raise KeyError(g)
        return s

    def unpack(self, groups, flat):
        """Flat tuple → dict of structured groups."""
        out = {}
        i = 0
        for g in groups:
            if g in ("params", "opt_state"):
                vals = flat[i:i + len(self.param_shapes)]
                i += len(self.param_shapes)
                out[g] = dict(zip(self.md.param_names, vals))
            elif g in ("lwc", "act_q"):
                vals = flat[i:i + 2 * self.n]
                i += 2 * self.n
                out[g] = [(vals[2 * j], vals[2 * j + 1]) for j in range(self.n)]
            elif g in ("e_list", "rvecs"):
                out[g] = list(flat[i:i + self.n])
                i += self.n
            else:
                out[g] = flat[i]
                i += 1
        assert i == len(flat), (i, len(flat))
        return out


# ---------------------------------------------------------------------------
# Export functions
# ---------------------------------------------------------------------------


def make_ctx(pk: Packing, u, mode, ste=False, use_pallas=False, collect=None):
    return QContext(
        mode=mode, ste=ste, use_pallas=use_pallas,
        act_q=u.get("act_q"), lwc=u.get("lwc"), e_list=u.get("e_list"),
        w_bits=pk.wb, a_bits=pk.ab, collect=collect,
    )


def loss_outputs(md, params, logits, labels):
    ce = cross_entropy(logits, labels)
    pred = jnp.argmax(logits, axis=-1).astype(jnp.float32)
    correct = jnp.sum((pred == labels).astype(jnp.float32))
    return ce, correct


def build_exports(md: models.ModelDef, wb, ab):
    in_shapes = md.conv_input_shapes(1)
    pk = Packing(md, wb, ab, in_shapes)
    ex = {}

    # ---- train: fp32 SGD momentum ----
    tg = ["params", "opt_state", "images_train", "labels_train", "lr"]

    def train_fn(*flat):
        u = pk.unpack(tg, flat)
        params, mom, lr = u["params"], u["opt_state"], u["lr"]

        def loss_of(p):
            logits = md.forward(p, u["images_train"], make_ctx(pk, u, "float"))
            ce, _ = loss_outputs(md, p, logits, u["labels_train"])
            return ce.mean()

        loss, grads = jax.value_and_grad(loss_of)(params)
        new_p, new_m = [], []
        for name in md.param_names:
            m2 = MOMENTUM * mom[name] + grads[name]
            new_m.append(m2)
            new_p.append(params[name] - lr * m2)
        return (*new_p, *new_m, loss)

    ex["train"] = (train_fn, tg, TRAIN_BATCH,
                   [f"param:{n}" for n in md.param_names]
                   + [f"mom:{n}" for n in md.param_names] + ["loss"])

    # ---- acts_float ----
    ag = ["params", "images_eval"]

    def acts_float_fn(*flat):
        u = pk.unpack(ag, flat)
        collect = []
        logits = md.forward(u["params"], u["images_eval"],
                            make_ctx(pk, u, "float", collect=collect))
        return (*collect, logits)

    ex["acts_float"] = (acts_float_fn, ag, EVAL_BATCH,
                        [f"act:{i}" for i in range(pk.n)] + ["logits"])

    # ---- fwd (+ pallas variant, + acts variant) ----
    fg = ["params", "lwc", "act_q", "e_list", "images_eval", "labels_eval"]

    def fwd_fn_base(flat, use_pallas=False, with_acts=False):
        u = pk.unpack(fg, flat)
        collect = [] if with_acts else None
        ctx = make_ctx(pk, u, "approx", use_pallas=use_pallas, collect=collect)
        logits = md.forward(u["params"], u["images_eval"], ctx)
        ce, correct = loss_outputs(md, u["params"], logits, u["labels_eval"])
        if with_acts:
            return (*collect, ce.sum(), correct)
        return (ce.sum(), correct, logits)

    ex["fwd"] = (lambda *f: fwd_fn_base(f), fg, EVAL_BATCH,
                 ["loss_sum", "correct", "logits"])
    ex["fwd_pallas"] = (lambda *f: fwd_fn_base(f, use_pallas=True), fg, EVAL_BATCH,
                        ["loss_sum", "correct", "logits"])
    ex["fwd_acts"] = (lambda *f: fwd_fn_base(f, with_acts=True), fg, EVAL_BATCH,
                      [f"act:{i}" for i in range(pk.n)] + ["loss_sum", "correct"])

    # ---- grad_e / hvp_e (estimation batch = train size) ----
    gg = ["params", "lwc", "act_q", "e_list", "images_train", "labels_train"]

    def loss_wrt_e(e_list, u):
        # STE rounding so ∂L/∂Y^(k) propagates through downstream
        # quantizers (the paper's PyTorch backprop does the same); without
        # it every layer but the last has zero gradient.
        u = dict(u, e_list=e_list)
        logits = md.forward(u["params"], u["images_train"],
                            make_ctx(pk, u, "approx", ste=True))
        ce, _ = loss_outputs(md, u["params"], logits, u["labels_train"])
        return ce.mean()

    def grad_e_fn(*flat):
        u = pk.unpack(gg, flat)
        loss, g = jax.value_and_grad(loss_wrt_e)(u["e_list"], u)
        return (loss, *g)

    ex["grad_e"] = (grad_e_fn, gg, TRAIN_BATCH,
                    ["loss"] + [f"g_e:{i}" for i in range(pk.n)])

    hg = gg + ["rvecs"]

    def hvp_e_fn(*flat):
        u = pk.unpack(hg, flat)
        grad_fn = jax.grad(loss_wrt_e)
        _, hr = jax.jvp(lambda e: grad_fn(e, u), (u["e_list"],), (u["rvecs"],))
        return tuple(hr)

    ex["hvp_e"] = (hvp_e_fn, hg, TRAIN_BATCH,
                   [f"h_r:{i}" for i in range(pk.n)])

    # ---- quad_e: per-layer exact Gauss–Newton quadratics, one call ----
    # q_k = ½ (J_k r_k)ᵀ H_L(z) (J_k r_k) with H_L the analytic softmax-CE
    # Hessian. jax.linearize shares the primal across the per-layer
    # tangent evaluations, so one execution covers every layer — the
    # estimation hot path of the rust pipeline (HessianMode::Exact).
    # NOTE: no labels input — H_L(z) needs only the logits, and the
    # stablehlo→HLO conversion strips unused parameters, so an unused
    # labels arg would break the manifest's input contract.
    qg = ["params", "lwc", "act_q", "e_list", "images_train", "rvecs"]

    def quad_e_fn(*flat):
        u = pk.unpack(qg, flat)

        def logits_of(e_list):
            uu = dict(u, e_list=e_list)
            return md.forward(uu["params"], uu["images_train"],
                              make_ctx(pk, uu, "approx", ste=True))

        z, lin = jax.linearize(logits_of, u["e_list"])
        p = jax.nn.softmax(z, axis=-1)
        batch = z.shape[0]
        outs = []
        for k in range(pk.n):
            probe = [u["rvecs"][j] if j == k else jnp.zeros_like(u["e_list"][j])
                     for j in range(pk.n)]
            jr = lin(probe)
            # per-sample H_L: (diag(p) − p pᵀ)/B on the mean-CE loss
            hjr = (p * jr - p * jnp.sum(p * jr, axis=-1, keepdims=True)) / batch
            outs.append(0.5 * jnp.vdot(jr, hjr))
        return tuple(outs)

    ex["quad_e"] = (quad_e_fn, qg, TRAIN_BATCH,
                    [f"quad:{i}" for i in range(pk.n)])

    # ---- calib: grads wrt LWC bounds (STE graph) ----
    cg = ["params", "lwc", "act_q", "e_list", "images_train", "labels_train"]

    def loss_wrt_lwc(lwc, u):
        u = dict(u, lwc=lwc)
        logits = md.forward(u["params"], u["images_train"],
                            make_ctx(pk, u, "approx", ste=True))
        ce, _ = loss_outputs(md, u["params"], logits, u["labels_train"])
        return ce.mean()

    def calib_fn(*flat):
        u = pk.unpack(cg, flat)
        loss, g = jax.value_and_grad(loss_wrt_lwc)(u["lwc"], u)
        flat_g = [x for pair in g for x in pair]
        return (loss, *flat_g)

    ex["calib"] = (calib_fn, cg, TRAIN_BATCH,
                   ["loss"] + [f"d{k}:{i}" for i in range(pk.n) for k in ("gamma", "beta")])

    # ---- retrain: grads wrt params + LWC (STE graph) ----
    def loss_wrt_all(pl, u):
        params, lwc = pl
        u = dict(u, lwc=lwc)
        logits = md.forward(params, u["images_train"],
                            make_ctx(pk, u, "approx", ste=True))
        ce, _ = loss_outputs(md, params, logits, u["labels_train"])
        return ce.mean()

    def retrain_fn(*flat):
        u = pk.unpack(cg, flat)
        loss, (gp, gl) = jax.value_and_grad(loss_wrt_all)((u["params"], u["lwc"]), u)
        flat_p = [gp[n] for n in md.param_names]
        flat_l = [x for pair in gl for x in pair]
        return (loss, *flat_p, *flat_l)

    ex["retrain"] = (retrain_fn, cg, TRAIN_BATCH,
                     ["loss"] + [f"gparam:{n}" for n in md.param_names]
                     + [f"d{k}:{i}" for i in range(pk.n) for k in ("gamma", "beta")])

    return pk, ex


# ---------------------------------------------------------------------------
# Manifest + driver
# ---------------------------------------------------------------------------


def manifest_json(md: models.ModelDef, cfg, wb, ab, pk: Packing, exe_files):
    in_shapes = pk.in_shapes
    layers = []
    for i, spec in enumerate(md.convs):
        c, h, w = in_shapes[i]
        assert c == spec.in_ch, (spec.name, c, spec.in_ch)
        ho, wo = spec.out_hw(h, w)
        layers.append({
            "name": spec.name, "index": i,
            "w_bits": wb[i], "a_bits": ab[i],
            "in_ch": spec.in_ch, "out_ch": spec.out_ch,
            "kernel": [spec.kernel, spec.kernel], "stride": spec.stride,
            "in_hw": [h, w], "out_hw": [ho, wo],
            "e_rows": 1 << ab[i], "e_cols": 1 << wb[i],
            "mults_per_image": spec.mults_per_image(h, w),
        })
    return {
        "model": md.name, "cfg": cfg,
        "num_classes": md.num_classes,
        "image_shape": list(md.image_shape),
        "train_batch": TRAIN_BATCH, "eval_batch": EVAL_BATCH,
        "layers": layers,
        "params": [{"name": n, "shape": list(md._param_shape(n))} for n in md.param_names],
        "opt_state": [{"name": f"{n}.m", "shape": list(md._param_shape(n))}
                      for n in md.param_names],
        "executables": exe_files,
    }


def export_set(md_name: str, cfg: str, out_root: str, only=None):
    md = models.build(md_name)
    wb, ab = bit_config(md, cfg)
    out_dir = os.path.join(out_root, f"{md_name}_{cfg}")
    os.makedirs(out_dir, exist_ok=True)
    pk, ex = build_exports(md, wb, ab)
    exe_files = {}
    for name, (fn, groups, batch, outputs) in ex.items():
        exe_files[name] = {"file": f"{name}.hlo.txt", "inputs": groups, "outputs": outputs}
        if only and name not in only:
            continue
        t0 = time.time()
        specs = pk.specs(groups, batch)
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(text)
        print(f"  {md_name}_{cfg}/{name}: {len(text) / 1e6:.1f} MB in {time.time() - t0:.1f}s",
              flush=True)
    mj = manifest_json(md, cfg, wb, ab, pk, exe_files)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(mj, f, indent=1)
    return out_dir


def export_spike(out_root: str):
    """Tiny fixed-scale approx-conv used by the rust bridge test."""
    from jax import lax

    def fwd(x, w, e_flat):
        q = 16
        sx, bx, sw, bw = 0.1, 0.0, 0.05, -0.4
        xq = jnp.clip(jnp.round((x - bx) / sx), 0, q - 1)
        wq = jnp.clip(jnp.round((w - bw) / sw), 0, q - 1)
        b, c, h, wd = x.shape
        o = w.shape[0]
        xp = jnp.pad(xq, ((0, 0), (0, 0), (1, 1), (1, 1)))
        patches = jnp.stack(
            [xp[:, :, i:i + h, j:j + wd] for i in range(3) for j in range(3)], axis=2)
        pm = patches.transpose(0, 3, 4, 1, 2).reshape(b, h * wd, c * 9)
        wm = wq.reshape(o, c * 9)
        exact = jnp.einsum("bpk,ok->bpo", pm, wm)
        idx = (pm[:, :, None, :] * q + wm[None, None, :, :]).astype(jnp.int32)
        err = jnp.take(e_flat, idx, axis=0).sum(axis=-1)
        y = sx * sw * (exact + err)
        loss = jnp.mean(y ** 2)
        return loss, jnp.sum(y), y.reshape(-1)[:4]

    os.makedirs(os.path.join(out_root, "spike"), exist_ok=True)
    specs = [jax.ShapeDtypeStruct((2, 3, 8, 8), jnp.float32),
             jax.ShapeDtypeStruct((4, 3, 3, 3), jnp.float32),
             jax.ShapeDtypeStruct((256,), jnp.float32)]
    text = to_hlo_text(jax.jit(fwd).lower(*specs))
    with open(os.path.join(out_root, "spike", "spike.hlo.txt"), "w") as f:
        f.write(text)
    print(f"  spike: {len(text) / 1e3:.0f} KB", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-root", default="../artifacts")
    ap.add_argument("--sets", default="",
                    help="comma-separated model_cfg pairs (default: full matrix)")
    ap.add_argument("--exes", default="", help="only these executables")
    args = ap.parse_args()
    sets = DEFAULT_SETS
    if args.sets:
        sets = []
        for s in args.sets.split(","):
            model, cfg = s.rsplit("_", 1)
            sets.append((model, cfg))
    only = set(args.exes.split(",")) if args.exes else None
    t0 = time.time()
    export_spike(args.out_root)
    for model, cfg in sets:
        export_set(model, cfg, args.out_root, only=only)
    print(f"artifacts complete in {time.time() - t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
