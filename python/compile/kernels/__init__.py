"""Layer-1 kernels."""
