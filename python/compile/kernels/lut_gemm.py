"""Layer 1 — Pallas LUT-GEMM kernel.

The compute hot-spot of an AppMul-substituted accelerator is
``out[m, n] = Σ_k LUT[x̂[m, k], ŵ[k, n]]`` (paper Eq. 5/8 inner term).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's ASIC
replaces each exact multiplier with an approximate one; on a TPU the natural
mapping is **table lookup as one-hot matmul** so the MXU does the work:

* pre-gather the LUT columns selected by the (static per-call) weight codes:
  ``EW[k, a, n] = LUT[a, ŵ[k, n]]`` — tiny, lives in VMEM;
* per M-tile, materialize the one-hot expansion of the activation codes in
  VMEM and contract ``(TM, K·Q) @ (K·Q, N)`` on the MXU.

BlockSpec tiles the activation-code matrix HBM→VMEM exactly where the
paper's accelerator streams activations through its multiplier array.
Lowered with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); the interpret path traces to plain HLO, so the same program
runs inside the AOT artifacts.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default M-tile: 128 rows keeps the one-hot expansion
# (128 × K·Q f32) comfortably inside a TPU core's VMEM for every
# (K, Q) used by the model zoo (≤ 288·16 at 4-bit, ≤ 72·256 at 8-bit).
DEFAULT_TILE_M = 128


def _lut_gemm_kernel(x_ref, ew_ref, o_ref, *, q: int):
    """One M-tile: one-hot expand codes, contract on the MXU.

    x_ref: [TM, K] activation codes (float-valued integers).
    ew_ref: [K, Q, N] pre-gathered LUT columns.
    o_ref: [TM, N] output tile.
    """
    x = x_ref[...]
    tm, k = x.shape
    _, q_dim, n = ew_ref.shape
    # One-hot along a new Q axis: (TM, K, Q). broadcasted_iota is
    # TPU-friendly (no 1-D iota restriction).
    iota = jax.lax.broadcasted_iota(jnp.float32, (tm, k, q_dim), 2)
    onehot = (x[:, :, None] == iota).astype(jnp.float32)
    # (TM, K·Q) @ (K·Q, N) — the MXU contraction.
    out = jnp.dot(
        onehot.reshape(tm, k * q_dim),
        ew_ref[...].reshape(k * q_dim, n),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = out


def lut_gemm(x_codes, ew, *, tile_m: int = DEFAULT_TILE_M, interpret: bool = True):
    """Pallas LUT-GEMM: ``out[m, n] = Σ_k EW[k, x̂[m, k], n]``.

    Args:
      x_codes: ``[M, K]`` float array of integer activation codes.
      ew: ``[K, Q, N]`` pre-gathered LUT columns
          (``EW[k, a, n] = LUT[a, ŵ[k, n]]``).
      tile_m: M-tile size (grid dimension).
      interpret: must stay True on CPU PJRT (see module docstring).
    Returns ``[M, N]`` f32.
    """
    m, k = x_codes.shape
    k2, q, n = ew.shape
    assert k == k2, (x_codes.shape, ew.shape)
    tile_m = min(tile_m, m)
    # Pad M to a tile multiple; padded rows use code 0 and are sliced off.
    m_pad = (-m) % tile_m
    if m_pad:
        x_codes = jnp.pad(x_codes, ((0, m_pad), (0, 0)))
    grid = ((m + m_pad) // tile_m,)
    out = pl.pallas_call(
        functools.partial(_lut_gemm_kernel, q=q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, k), lambda i: (i, 0)),
            pl.BlockSpec((k, q, n), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_m, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m + m_pad, n), jnp.float32),
        interpret=interpret,
    )(x_codes.astype(jnp.float32), ew.astype(jnp.float32))
    return out[:m]


def build_ew(lut, w_codes):
    """Pre-gather LUT columns by weight codes.

    Args:
      lut: ``[Qx, Qw]`` table.
      w_codes: ``[K, N]`` float array of integer weight codes.
    Returns ``EW[k, a, n] = LUT[a, ŵ[k, n]]`` with shape ``[K, Qx, N]``.
    """
    idx = jax.lax.stop_gradient(w_codes).astype(jnp.int32)  # [K, N]
    # lut[:, idx] -> [Qx, K, N]; move Qx inside.
    return jnp.transpose(lut[:, idx], (1, 0, 2))


def lut_gemm_from_codes(x_codes, w_codes, lut, **kw):
    """Convenience wrapper: codes + LUT → LUT-GEMM output."""
    return lut_gemm(x_codes, build_ew(lut, w_codes), **kw)


def vmem_bytes_estimate(k: int, q: int, n: int, tile_m: int = DEFAULT_TILE_M) -> int:
    """Static VMEM footprint estimate for one grid step (DESIGN.md §Perf).

    Counts the x tile, the pre-gathered EW block, the one-hot expansion and
    the output tile, all f32. Used by the perf notes, not at runtime.
    """
    x_tile = tile_m * k
    ew_blk = k * q * n
    onehot = tile_m * k * q
    out_tile = tile_m * n
    return 4 * (x_tile + ew_blk + onehot + out_tile)
