"""Pure-jnp / pure-python oracles for the LUT-GEMM kernel (Layer 1 spec).

These are the correctness ground truth: deliberately naive, loop-based where
practical, and used by pytest (incl. hypothesis sweeps) to check both the
Pallas kernel and the fast one-hot-GEMM path in ``layers.py``.
"""

import numpy as np


def lut_gemm_ref(x_codes, w_codes, lut):
    """Naive LUT GEMM: ``out[m, n] = sum_k LUT[x[m, k], w[k, n]]``.

    Args:
      x_codes: ``[M, K]`` integer array (activation codes).
      w_codes: ``[K, N]`` integer array (weight codes).
      lut: ``[Qx, Qw]`` table (the AppMul LUT or its error matrix E).
    Returns ``[M, N]`` float64 array.
    """
    x_codes = np.asarray(x_codes).astype(np.int64)
    w_codes = np.asarray(w_codes).astype(np.int64)
    lut = np.asarray(lut)
    m_dim, k_dim = x_codes.shape
    k2, n_dim = w_codes.shape
    assert k_dim == k2, (x_codes.shape, w_codes.shape)
    out = np.zeros((m_dim, n_dim), dtype=np.float64)
    for m in range(m_dim):
        for n in range(n_dim):
            acc = 0.0
            for k in range(k_dim):
                acc += float(lut[x_codes[m, k], w_codes[k, n]])
            out[m, n] = acc
    return out


def counting_matrix_ref(x_codes, w_codes, qx, qw):
    """Aggregate counting matrix ``T[a, b]`` = #times code pair (a, b) is
    multiplied in the GEMM (paper §IV-B, summed over all output entries)."""
    x_codes = np.asarray(x_codes).astype(np.int64)
    w_codes = np.asarray(w_codes).astype(np.int64)
    t = np.zeros((qx, qw), dtype=np.int64)
    m_dim, k_dim = x_codes.shape
    _, n_dim = w_codes.shape
    for m in range(m_dim):
        for n in range(n_dim):
            for k in range(k_dim):
                t[x_codes[m, k], w_codes[k, n]] += 1
    return t


def conv2d_ref(x, w, stride, pad):
    """Naive float conv (NCHW ⊛ OIHW), for model-shape oracle tests."""
    x = np.asarray(x, dtype=np.float64)
    w = np.asarray(w, dtype=np.float64)
    b_dim, c_dim, h_dim, w_dim = x.shape
    o_dim, c2, kh, kw = w.shape
    assert c_dim == c2
    xp = np.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    ho = (h_dim + 2 * pad - kh) // stride + 1
    wo = (w_dim + 2 * pad - kw) // stride + 1
    out = np.zeros((b_dim, o_dim, ho, wo))
    for b in range(b_dim):
        for o in range(o_dim):
            for i in range(ho):
                for j in range(wo):
                    patch = xp[b, :, i * stride:i * stride + kh, j * stride:j * stride + kw]
                    out[b, o, i, j] = np.sum(patch * w[o])
    return out


def paper_worked_example():
    """The worked 3×3 / 2-bit example from paper §IV-B.

    Returns (X, W, C_expected, E). The paper's convolution there is the
    single *valid* position (3×3 kernel on a 3×3 input, correlation without
    flipping). NOTE: the paper's printed C has a typo in row 2 — the pair
    (2, 3) occurs twice (X entries 2 at (0,2)/(2,0) multiply W entries 3 at
    (0,2)/(2,0)), so C[2,3]=2, but the paper prints C[2,2]=2. We return the
    corrected matrix; every other entry matches the paper verbatim.
    """
    x = np.array([[0, 1, 2], [3, 0, 1], [2, 3, 0]])
    w = np.array([[1, 2, 3], [0, 1, 2], [3, 0, 1]])
    c = np.array([
        [0, 3, 0, 0],
        [0, 0, 2, 0],
        [0, 0, 0, 2],
        [2, 0, 0, 0],
    ])
    e = np.array([
        [0, 1, 3, 2],
        [-1, 0, 2, 0],
        [0, -2, 2, 0],
        [2, 1, 1, 0],
    ])
    return x, w, c, e
