"""Layer 2 — model zoo (mini ResNet / VGG / SqueezeNet).

Channel-scaled versions of the paper's evaluation models, preserving the
topological structure that drives layer-wise sensitivity (residual blocks,
VGG conv stacks, Fire modules) on 3×16×16 synthetic-CIFAR images. The
substitution rationale is documented in DESIGN.md §3.

Every conv is a substitutable layer (the paper applies one AppMul per conv
layer, including residual shortcuts); the final linear classifier stays
exact, as in prior AppMul work.
"""

from dataclasses import dataclass, field
from typing import Callable, List

import jax
import jax.numpy as jnp

from .layers import ConvSpec, QContext, conv_apply, avg_pool, global_avg_pool, linear


@dataclass
class ModelDef:
    name: str
    num_classes: int
    image_shape: tuple  # (C, H, W)
    convs: List[ConvSpec]
    fc_in: int
    forward: Callable  # (params, x, ctx) -> logits
    param_names: List[str] = field(default_factory=list)

    def init_params(self, seed: int = 0):
        """He-normal conv weights, zero biases, LeCun fc."""
        key = jax.random.PRNGKey(seed)
        params = {}
        for spec in self.convs:
            key, k1 = jax.random.split(key)
            fan_in = spec.in_ch * spec.kernel * spec.kernel
            std = (2.0 / fan_in) ** 0.5
            params[f"{spec.name}.w"] = std * jax.random.normal(
                k1, (spec.out_ch, spec.in_ch, spec.kernel, spec.kernel), jnp.float32
            )
            params[f"{spec.name}.b"] = jnp.zeros((spec.out_ch,), jnp.float32)
        key, k1 = jax.random.split(key)
        params["fc.w"] = (1.0 / self.fc_in**0.5) * jax.random.normal(
            k1, (self.fc_in, self.num_classes), jnp.float32
        )
        params["fc.b"] = jnp.zeros((self.num_classes,), jnp.float32)
        assert list(params.keys()) == self.param_names
        return params

    def _param_shape(self, name: str):
        if name == "fc.w":
            return (self.fc_in, self.num_classes)
        if name == "fc.b":
            return (self.num_classes,)
        base, kind = name.rsplit(".", 1)
        spec = next(s for s in self.convs if s.name == base)
        if kind == "w":
            return (spec.out_ch, spec.in_ch, spec.kernel, spec.kernel)
        return (spec.out_ch,)


def _finish_modeldef(md: ModelDef) -> ModelDef:
    md.param_names = [f"{s.name}.{k}" for s in md.convs for k in ("w", "b")] + [
        "fc.w",
        "fc.b",
    ]

    def conv_input_shapes(batch: int = 1):
        """Record each conv's input (C, H, W) by abstract evaluation."""
        collected: List = []
        ctx = QContext(
            mode="quant",
            ste=False,
            act_q=[(jnp.float32(0.1), jnp.float32(0.0))] * len(md.convs),
            lwc=[(jnp.float32(4.0), jnp.float32(4.0))] * len(md.convs),
            w_bits=[4] * len(md.convs),
            a_bits=[4] * len(md.convs),
            collect=collected,
        )
        params = {
            n: jax.ShapeDtypeStruct(md._param_shape(n), jnp.float32)
            for n in md.param_names
        }
        x = jax.ShapeDtypeStruct((batch, *md.image_shape), jnp.float32)
        jax.eval_shape(lambda p, xx: md.forward(p, xx, ctx), params, x)
        return [tuple(c.shape[1:]) for c in collected]

    md.conv_input_shapes = conv_input_shapes  # type: ignore[method-assign]
    return md


# ---------------------------------------------------------------------------
# ResNet (CIFAR-style: 3 stages, stride-2 transitions, identity/projection
# shortcuts). depth = 2 + 6·blocks_per_stage convs (+ projections).
# ---------------------------------------------------------------------------


def make_resnet(name: str, blocks_per_stage: int, widths=(8, 16, 32), num_classes: int = 10,
                image_shape=(3, 16, 16)) -> ModelDef:
    convs: List[ConvSpec] = [ConvSpec("conv0", image_shape[0], widths[0], 3)]
    order = []  # (kind, payload) list mirrored by forward()
    in_ch = widths[0]
    for s, width in enumerate(widths):
        for b in range(blocks_per_stage):
            stride = 2 if (s > 0 and b == 0) else 1
            proj = stride != 1 or in_ch != width
            base = f"s{s}b{b}"
            convs.append(ConvSpec(f"{base}.c1", in_ch, width, 3, stride))
            convs.append(ConvSpec(f"{base}.c2", width, width, 3, 1))
            if proj:
                convs.append(ConvSpec(f"{base}.sc", in_ch, width, 1, stride, pad=0))
            order.append((len(convs) - (3 if proj else 2), proj))
            in_ch = width

    def forward(params, x, ctx: QContext):
        h = jax.nn.relu(conv_apply(0, convs[0], params, ctx, x))
        i = 1
        for first_idx, proj in order:
            assert i == first_idx
            h1 = jax.nn.relu(conv_apply(i, convs[i], params, ctx, h))
            h2 = conv_apply(i + 1, convs[i + 1], params, ctx, h1)
            if proj:
                sc = conv_apply(i + 2, convs[i + 2], params, ctx, h)
                i += 3
            else:
                sc = h
                i += 2
            h = jax.nn.relu(h2 + sc)
        feat = global_avg_pool(h)
        return linear(feat, params["fc.w"], params["fc.b"])

    return _finish_modeldef(
        ModelDef(name, num_classes, image_shape, convs, widths[-1], forward)
    )


# ---------------------------------------------------------------------------
# VGG-style conv stack ('M' = 2×2 avg-pool), GAP head.
# ---------------------------------------------------------------------------


def make_vgg(name: str, cfg=(8, 8, "M", 16, 16, "M", 32, 32, "M"), num_classes: int = 10,
             image_shape=(3, 16, 16)) -> ModelDef:
    convs: List[ConvSpec] = []
    in_ch = image_shape[0]
    for item in cfg:
        if item == "M":
            continue
        convs.append(ConvSpec(f"conv{len(convs)}", in_ch, int(item), 3))
        in_ch = int(item)
    last = in_ch

    def forward(params, x, ctx: QContext):
        h = x
        ci = 0
        for item in cfg:
            if item == "M":
                h = avg_pool(h, 2)
            else:
                h = jax.nn.relu(conv_apply(ci, convs[ci], params, ctx, h))
                ci += 1
        feat = global_avg_pool(h)
        return linear(feat, params["fc.w"], params["fc.b"])

    return _finish_modeldef(ModelDef(name, num_classes, image_shape, convs, last, forward))


# ---------------------------------------------------------------------------
# SqueezeNet-style Fire modules (squeeze 1×1 → expand 1×1 ∥ 3×3, concat).
# ---------------------------------------------------------------------------


def make_squeezenet(name: str, num_classes: int = 100, image_shape=(3, 16, 16)) -> ModelDef:
    convs: List[ConvSpec] = [ConvSpec("conv0", image_shape[0], 8, 3)]
    fires = [(8, 4, 8), (16, 8, 16)]  # (in, squeeze, expand)
    for f, (cin, cs, ce) in enumerate(fires):
        convs.append(ConvSpec(f"fire{f}.sq", cin, cs, 1, pad=0))
        convs.append(ConvSpec(f"fire{f}.e1", cs, ce, 1, pad=0))
        convs.append(ConvSpec(f"fire{f}.e3", cs, ce, 3))
    last = 2 * fires[-1][2]

    def forward(params, x, ctx: QContext):
        h = jax.nn.relu(conv_apply(0, convs[0], params, ctx, x))
        h = avg_pool(h, 2)
        i = 1
        for f in range(len(fires)):
            sq = jax.nn.relu(conv_apply(i, convs[i], params, ctx, h))
            e1 = jax.nn.relu(conv_apply(i + 1, convs[i + 1], params, ctx, sq))
            e3 = jax.nn.relu(conv_apply(i + 2, convs[i + 2], params, ctx, sq))
            h = jnp.concatenate([e1, e3], axis=1)
            if f + 1 < len(fires):
                h = avg_pool(h, 2)
            i += 3
        feat = global_avg_pool(h)
        return linear(feat, params["fc.w"], params["fc.b"])

    return _finish_modeldef(ModelDef(name, num_classes, image_shape, convs, last, forward))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def build(name: str) -> ModelDef:
    builders = {
        "resnet8": lambda: make_resnet("resnet8", 1),
        "resnet14": lambda: make_resnet("resnet14", 2),
        "resnet20": lambda: make_resnet("resnet20", 3),
        "vgg11": lambda: make_vgg("vgg11"),
        "squeezenet": lambda: make_squeezenet("squeezenet"),
    }
    if name not in builders:
        raise KeyError(f"unknown model '{name}' (have {sorted(builders)})")
    return builders[name]()


MODEL_NAMES = ["resnet8", "resnet14", "resnet20", "vgg11", "squeezenet"]
