"""Model-zoo shape/consistency tests + AOT export contract tests."""

import json

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import aot, models
from compile.layers import QContext


@pytest.mark.parametrize("name,n_convs", [
    ("resnet8", 9), ("resnet14", 15), ("resnet20", 21),
    ("vgg11", 6), ("squeezenet", 7),
])
def test_zoo_geometry(name, n_convs):
    md = models.build(name)
    assert len(md.convs) == n_convs
    shapes = md.conv_input_shapes(1)
    assert len(shapes) == n_convs
    # every conv's declared in_ch matches the traced input
    for spec, (c, _, _) in zip(md.convs, shapes):
        assert spec.in_ch == c, spec.name


@pytest.mark.parametrize("name", models.MODEL_NAMES)
def test_float_forward_shapes_and_finite(name):
    md = models.build(name)
    params = md.init_params(0)
    x = jnp.array(np.random.default_rng(0).normal(size=(2, *md.image_shape)),
                  jnp.float32)
    logits = md.forward(params, x, QContext(mode="float"))
    assert logits.shape == (2, md.num_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_quant_forward_close_to_float_at_8_bits():
    """8-bit quantization should barely move the logits of a random net."""
    md = models.build("resnet8")
    params = md.init_params(0)
    rng = np.random.default_rng(1)
    x = jnp.array(rng.uniform(0, 1, size=(2, *md.image_shape)), jnp.float32)
    n = len(md.convs)
    y_f = md.forward(params, x, QContext(mode="float"))
    ctx = QContext(
        mode="quant",
        act_q=[(jnp.float32(4.0 / 255), jnp.float32(-2.0))] * n,
        lwc=[(jnp.float32(8.0), jnp.float32(8.0))] * n,
        w_bits=[8] * n, a_bits=[8] * n,
    )
    y_q = md.forward(params, x, ctx)
    assert float(jnp.max(jnp.abs(y_f - y_q))) < 0.2 * float(jnp.max(jnp.abs(y_f)) + 1)


def test_bit_config_mixed_average():
    md = models.build("resnet20")
    wb, ab = aot.bit_config(md, "mixed")
    assert wb == ab
    assert wb[0] == 8 and wb[-1] == 2
    avg = sum(wb) / len(wb)
    assert 3.0 <= avg <= 5.0


def test_bit_config_uniform_parse():
    md = models.build("resnet8")
    wb, ab = aot.bit_config(md, "w4a8")
    assert set(wb) == {4} and set(ab) == {8}
    with pytest.raises(KeyError):
        aot.bit_config(md, "bogus")


def test_packing_spec_and_unpack_roundtrip():
    md = models.build("resnet8")
    wb, ab = aot.bit_config(md, "w3a3")
    pk = aot.Packing(md, wb, ab, md.conv_input_shapes(1))
    groups = ["params", "lwc", "act_q", "e_list", "images_train", "labels_train"]
    specs = pk.specs(groups, aot.TRAIN_BATCH)
    vals = [jnp.zeros(s.shape, s.dtype) for s in specs]
    u = pk.unpack(groups, vals)
    assert set(u["params"].keys()) == set(md.param_names)
    assert len(u["lwc"]) == len(md.convs)
    assert len(u["e_list"]) == len(md.convs)
    assert all(e.shape == (64,) for e in u["e_list"])  # 2^3 · 2^3
    assert u["images_train"].shape == (aot.TRAIN_BATCH, *md.image_shape)


def test_export_set_writes_manifest_and_hlo(tmp_path):
    out = str(tmp_path)
    aot.export_set("resnet8", "w2a2", out, only={"fwd"})
    mdir = tmp_path / "resnet8_w2a2"
    mj = json.loads((mdir / "manifest.json").read_text())
    assert mj["model"] == "resnet8" and mj["cfg"] == "w2a2"
    assert len(mj["layers"]) == 9
    lay0 = mj["layers"][0]
    assert lay0["e_rows"] == 4 and lay0["e_cols"] == 4
    # mults formula (paper §IV-D): N_O·H·W·N_I·W_K·H_K
    assert lay0["mults_per_image"] == 8 * 16 * 16 * 3 * 3 * 3
    hlo = (mdir / "fwd.hlo.txt").read_text()
    assert hlo.startswith("HloModule")
    # executable contract recorded for every exe even when not lowered
    assert set(mj["executables"]) == {
        "train", "acts_float", "fwd", "fwd_pallas", "fwd_acts",
        "grad_e", "hvp_e", "quad_e", "calib", "retrain",
    }


def test_grad_e_matches_finite_difference():
    """End-to-end ∇_E check through a full (tiny) model."""
    md = models.build("resnet8")
    params = md.init_params(0)
    wb, ab = aot.bit_config(md, "w2a2")
    n = len(md.convs)
    rng = np.random.default_rng(0)
    x = jnp.array(rng.uniform(0, 1, size=(4, *md.image_shape)), jnp.float32)
    labels = jnp.array(rng.integers(0, 10, size=4), jnp.float32)
    act_q = [(jnp.float32(0.3), jnp.float32(0.0))] * n
    lwc = [(jnp.float32(8.0), jnp.float32(8.0))] * n
    e_list = [jnp.zeros(16) for _ in range(n)]

    def loss_of(e_list):
        # STE as in the exported grad_e graph (see aot.loss_wrt_e).
        ctx = QContext(mode="approx", ste=True, act_q=act_q, lwc=lwc,
                       e_list=e_list, w_bits=wb, a_bits=ab)
        logits = md.forward(params, x, ctx)
        from compile.layers import cross_entropy
        return cross_entropy(logits, labels).mean()

    g = jax.grad(loss_of)(e_list)
    # With STE, the error of EVERY layer influences the loss estimate.
    for i in range(n):
        assert float(jnp.abs(g[i]).sum()) > 0.0, f"zero grad at layer {i}"
    # FD is only well-posed where no downstream rounding intervenes: the
    # last conv layer (its output reaches the loss through relu/GAP/fc).
    layer = n - 1
    eps = 1e-3
    checked = 0
    for coord in range(16):
        if abs(float(g[layer][coord])) < 1e-4:
            continue
        ep = [e.at[coord].add(eps) if i == layer else e for i, e in enumerate(e_list)]
        em = [e.at[coord].add(-eps) if i == layer else e for i, e in enumerate(e_list)]
        fd = (float(loss_of(ep)) - float(loss_of(em))) / (2 * eps)
        np.testing.assert_allclose(float(g[layer][coord]), fd, rtol=0.05, atol=1e-4)
        checked += 1
    assert checked >= 2


def test_hvp_matches_finite_difference_of_grad():
    md = models.build("resnet8")
    params = md.init_params(1)
    wb, ab = aot.bit_config(md, "w2a2")
    n = len(md.convs)
    rng = np.random.default_rng(2)
    x = jnp.array(rng.uniform(0, 1, size=(4, *md.image_shape)), jnp.float32)
    labels = jnp.array(rng.integers(0, 10, size=4), jnp.float32)
    act_q = [(jnp.float32(0.3), jnp.float32(0.0))] * n
    lwc = [(jnp.float32(8.0), jnp.float32(8.0))] * n
    e0 = [jnp.zeros(16) for _ in range(n)]
    r = [jnp.array(rng.normal(size=16), jnp.float32) for _ in range(n)]

    def loss_of(e_list):
        ctx = QContext(mode="approx", ste=True, act_q=act_q, lwc=lwc,
                       e_list=e_list, w_bits=wb, a_bits=ab)
        logits = md.forward(params, x, ctx)
        from compile.layers import cross_entropy
        return cross_entropy(logits, labels).mean()

    grad_fn = jax.grad(loss_of)
    _, hr = jax.jvp(grad_fn, (e0,), (r,))

    # Independent Gauss–Newton computation: with fixed codes, the logits are
    # locally affine in e (conv/relu/STE tangents are linear), so
    # H_e = Jᵀ·H_L(z)·J exactly, with H_L(z) the analytic softmax-CE Hessian
    # (diag(p) − p pᵀ)/B per sample. FD is ill-posed here (the loss gradient
    # is discontinuous at code flips), so this is the correct oracle.
    def logits_of(el):
        ctx = QContext(mode="approx", ste=True, act_q=act_q, lwc=lwc,
                       e_list=el, w_bits=wb, a_bits=ab)
        return md.forward(params, x, ctx)

    z, jr = jax.jvp(logits_of, (e0,), (r,))  # J·r
    p = jax.nn.softmax(z, axis=-1)
    batch = z.shape[0]
    # u_s = H_s · (J r)_s with H_s = (diag(p_s) − p_s p_sᵀ)/B
    u = (p * jr - p * jnp.sum(p * jr, axis=-1, keepdims=True)) / batch
    _, vjp_fn = jax.vjp(logits_of, e0)
    (hr_gn,) = vjp_fn(u)
    for i in range(n):
        np.testing.assert_allclose(
            np.array(hr[i]), np.array(hr_gn[i]), rtol=1e-3, atol=1e-5)
