"""Layer-2 correctness: quantizers, error-conv formulations, Eq. 8 identity."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import quant
from compile.layers import (
    ConvSpec, QContext, conv_apply, error_conv, error_gemm_gather,
    error_gemm_onehot, im2col, cross_entropy,
)
from compile.kernels import lut_gemm as lk
from compile.kernels import ref


# ---------------------------------------------------------------------------
# quantizers
# ---------------------------------------------------------------------------


def test_act_quant_roundtrip_exact_grid():
    """Values on the quantization grid survive the round trip exactly."""
    s, b, bits = 0.25, -1.0, 3
    codes = jnp.arange(8, dtype=jnp.float32)
    x = s * codes + b
    q, deq = quant.quantize_act(x, s, b, bits)
    np.testing.assert_allclose(np.array(q), np.array(codes))
    np.testing.assert_allclose(np.array(deq), np.array(x), atol=1e-6)


def test_act_quant_clips_out_of_range():
    q, _ = quant.quantize_act(jnp.array([-10.0, 10.0]), 0.1, 0.0, 2)
    assert q.tolist() == [0.0, 3.0]


@settings(max_examples=20, deadline=None)
@given(bits=st.integers(2, 8), seed=st.integers(0, 10**6))
def test_act_quant_error_bounded_by_half_step(bits, seed):
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1, 1, size=64).astype(np.float32)
    s, b = quant.act_qparams_init(-1.0, 1.0, bits)
    _, deq = quant.quantize_act(jnp.array(x), s, b, bits)
    assert np.max(np.abs(np.array(deq) - x)) <= s / 2 + 1e-6


def test_lwc_wide_bounds_recover_minmax_quant():
    """γ=β=+8 ⇒ σ≈1 ⇒ LWC reduces to per-channel min/max quantization."""
    rng = np.random.default_rng(0)
    w = jnp.array(rng.normal(size=(4, 3, 3, 3)).astype(np.float32))
    q, deq, s, b = quant.lwc_weight_quant(w, 8.0, 8.0, 4)
    assert s.shape == (4, 1, 1, 1) and b.shape == (4, 1, 1, 1)
    # every channel spans its own code range...
    q_np = np.array(q)
    for o in range(4):
        assert q_np[o].min() == 0.0 and q_np[o].max() == 15.0
    # ...and round-trips within half a per-channel step
    err = np.abs(np.array(deq) - np.array(w))
    assert np.all(err <= np.array(s) / 2 * (1 + 1e-3) + 1e-5)


def test_lwc_tight_bounds_clip():
    w = jnp.array([-4.0, -1.0, 0.0, 1.0, 4.0])
    # σ(-2) ≈ 0.119: bounds ≈ ±0.48 — everything clips hard.
    _, deq, _, _ = quant.lwc_weight_quant(w, -2.0, -2.0, 4)
    assert float(jnp.max(jnp.abs(deq))) < 0.5


def test_lwc_gradients_flow_to_bounds():
    """Autodiff through Eq. 6 matches the paper's piecewise gradient: only
    clipped weights contribute to ∂/∂γ, ∂/∂β."""
    w = jnp.array([-4.0, -0.1, 0.1, 4.0])

    def f(gamma, beta):
        _, deq, _, _ = quant.lwc_weight_quant(w, gamma, beta, 8, ste=True)
        return jnp.sum(deq)

    dg, db = jax.grad(f, argnums=(0, 1))(0.0, 0.0)
    # lower bound moves with γ via σ'(γ)·min(w): negative direction
    assert float(dg) < 0.0
    assert float(db) > 0.0


def test_round_ste_gradient_is_identity():
    g = jax.grad(lambda x: quant.round_ste(x * 3.0))(0.3)
    assert float(g) == 3.0


# ---------------------------------------------------------------------------
# error-term formulations agree with the oracle and each other
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("qx,qw", [(4, 4), (16, 16), (4, 16), (256, 256)])
def test_error_gemm_formulations_agree(qx, qw):
    rng = np.random.default_rng(qx + qw)
    b, p, k, o = 2, 6, 5, 3
    x = jnp.array(rng.integers(0, qx, size=(b, p, k)), jnp.float32)
    w = jnp.array(rng.integers(0, qw, size=(o, k)), jnp.float32)
    e2d = rng.normal(size=(qx, qw)).astype(np.float32)
    e_flat = jnp.array(e2d.reshape(-1))
    got_gather = error_gemm_gather(x, w, e_flat, qw)
    # oracle per batch entry
    for bi in range(b):
        want = ref.lut_gemm_ref(np.array(x[bi]), np.array(w).T, e2d)
        np.testing.assert_allclose(np.array(got_gather[bi]), want, rtol=1e-4, atol=1e-4)
    if qx <= 32:
        ew = lk.build_ew(jnp.array(e2d), w.T)
        got_oh = error_gemm_onehot(x, ew)
        np.testing.assert_allclose(np.array(got_oh), np.array(got_gather), rtol=1e-4,
                                   atol=1e-4)


def test_error_gemm_gather_k_padding():
    """K not a multiple of the chunk must not change the result (the padded
    slots' e[0] contribution is subtracted)."""
    rng = np.random.default_rng(5)
    qx = qw = 4
    b, p, k, o = 1, 3, 9, 2  # k=9, chunk=8 → one padded slot
    x = jnp.array(rng.integers(0, qx, size=(b, p, k)), jnp.float32)
    w = jnp.array(rng.integers(0, qw, size=(o, k)), jnp.float32)
    e2d = rng.normal(size=(qx, qw)).astype(np.float32)
    e2d[0, 0] = 17.0  # make a wrong-padding bug loud
    got = error_gemm_gather(x, w, jnp.array(e2d.reshape(-1)), qw)
    want = ref.lut_gemm_ref(np.array(x[0]), np.array(w).T, e2d)
    np.testing.assert_allclose(np.array(got[0]), want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Eq. 8: approx conv == exact quant conv + s_x·s_w·(counting ⊙ E)
# ---------------------------------------------------------------------------


def _quant_setup(seed=0, bits=4):
    rng = np.random.default_rng(seed)
    spec = ConvSpec("c", 3, 4, 3)
    x = jnp.array(rng.normal(size=(2, 3, 8, 8)).astype(np.float32))
    params = {
        "c.w": jnp.array(0.3 * rng.normal(size=(4, 3, 3, 3)).astype(np.float32)),
        "c.b": jnp.array(rng.normal(size=(4,)).astype(np.float32)),
    }
    q = 1 << bits
    e2d = rng.normal(size=(q, q)).astype(np.float32)
    ctx_kw = dict(
        act_q=[(jnp.float32(0.05), jnp.float32(-1.0))],
        lwc=[(jnp.float32(8.0), jnp.float32(8.0))],
        w_bits=[bits], a_bits=[bits],
    )
    return spec, x, params, e2d, ctx_kw


def test_eq8_identity_zero_error_matches_quant():
    spec, x, params, _, kw = _quant_setup()
    y_quant = conv_apply(0, spec, params, QContext(mode="quant", **kw), x)
    kw2 = dict(kw, e_list=[jnp.zeros(256)])
    y_approx = conv_apply(0, spec, params, QContext(mode="approx", **kw2), x)
    np.testing.assert_allclose(np.array(y_quant), np.array(y_approx), atol=1e-5)


def test_eq8_identity_error_term_via_counting_matrix():
    """Y_approx - Y_exact summed per channel == s_x·s_w[o]·Σ_ab T_o[a,b]·E[a,b]
    (aggregate counting-matrix form of Eq. 8, per output channel since weight
    quantization is per-channel)."""
    spec, x, params, e2d, kw = _quant_setup()
    bits = 4
    y_quant = conv_apply(0, spec, params, QContext(mode="quant", **kw), x)
    kw2 = dict(kw, e_list=[jnp.array(e2d.reshape(-1))])
    y_approx = conv_apply(0, spec, params, QContext(mode="approx", **kw2), x)

    # independent counting-matrix computation, per output channel
    s_x, b_x = 0.05, -1.0
    xq, _ = quant.quantize_act(x, s_x, b_x, bits)
    wq, _, s_w, _ = quant.lwc_weight_quant(params["c.w"], 8.0, 8.0, bits)
    patches, _ = im2col(xq, 3, 1, 1)
    s_w = np.array(s_w).reshape(-1)
    delta_per_ch = np.array(jnp.sum(y_approx - y_quant, axis=(0, 2, 3)))
    for o in range(4):
        t = np.zeros((16, 16), np.int64)
        for bi in range(2):
            t += ref.counting_matrix_ref(
                np.array(patches[bi]),
                np.array(wq.reshape(4, -1))[o:o + 1].T, 16, 16)
        want = float(s_x) * float(s_w[o]) * float(np.sum(t * e2d))
        np.testing.assert_allclose(delta_per_ch[o], want, rtol=1e-3)


def test_paper_worked_example_counting_matrix():
    """§IV-B worked example: C matches the paper's printed matrix."""
    x, w, c_want, _ = ref.paper_worked_example()
    # single valid position: patch == whole X, element-wise with W
    t = ref.counting_matrix_ref(x.reshape(1, -1), w.reshape(-1, 1), 4, 4)
    np.testing.assert_array_equal(t, c_want)


def test_grad_wrt_e_is_counting_weighted(tmp_path):
    """∇_E of (sum of approx outputs) equals s_x·s_w·T — the gather
    transpose IS the counting matrix (Eq. 10 with dL/dY ≡ 1)."""
    spec, x, params, e2d, kw = _quant_setup()
    bits = 4

    def f(e_flat):
        ctx = QContext(mode="approx", **dict(kw, e_list=[e_flat]))
        return jnp.sum(conv_apply(0, spec, params, ctx, x))

    g = jax.grad(f)(jnp.zeros(256))
    s_x, b_x = 0.05, -1.0
    xq, _ = quant.quantize_act(x, s_x, b_x, bits)
    wq, _, s_w, _ = quant.lwc_weight_quant(params["c.w"], 8.0, 8.0, bits)
    patches, _ = im2col(xq, 3, 1, 1)
    s_w = np.array(s_w).reshape(-1)
    want = np.zeros((16, 16))
    for o in range(4):
        t = np.zeros((16, 16), np.int64)
        for bi in range(2):
            t += ref.counting_matrix_ref(
                np.array(patches[bi]),
                np.array(wq.reshape(4, -1))[o:o + 1].T, 16, 16)
        want += float(s_x) * float(s_w[o]) * t
    np.testing.assert_allclose(np.array(g).reshape(16, 16), want, rtol=1e-3, atol=1e-5)


def test_cross_entropy_matches_numpy():
    logits = jnp.array([[2.0, 0.0, -1.0], [0.0, 1.0, 0.0]])
    labels = jnp.array([0.0, 2.0])
    ce = cross_entropy(logits, labels)
    p0 = np.exp(2.0) / (np.exp(2.0) + 1 + np.exp(-1.0))
    p1 = 1.0 / (1 + np.e + 1)
    np.testing.assert_allclose(np.array(ce), [-np.log(p0), -np.log(p1)], rtol=1e-5)
