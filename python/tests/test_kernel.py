"""Layer-1 correctness: Pallas LUT-GEMM vs the pure oracle.

The Pallas kernel is the CORE correctness signal of the compile path — it is
what ends up inside the `fwd_pallas` artifact the rust runtime executes.
Hypothesis sweeps shapes and bitwidths.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import lut_gemm as lk
from compile.kernels import ref


def random_case(rng, m, k, n, qx, qw):
    x = rng.integers(0, qx, size=(m, k))
    w = rng.integers(0, qw, size=(k, n))
    lut = rng.normal(size=(qx, qw)).astype(np.float32)
    return x, w, lut


@pytest.mark.parametrize("m,k,n,qx,qw", [
    (4, 3, 2, 4, 4),
    (16, 9, 8, 16, 16),
    (130, 27, 8, 16, 16),   # exercises M padding (tile 128)
    (8, 5, 3, 4, 8),        # rectangular LUT (w≠a bits)
])
def test_pallas_matches_oracle(m, k, n, qx, qw):
    rng = np.random.default_rng(m * 1000 + k)
    x, w, lut = random_case(rng, m, k, n, qx, qw)
    want = ref.lut_gemm_ref(x, w, lut)
    ew = lk.build_ew(jnp.array(lut), jnp.array(w, dtype=jnp.float32))
    got = lk.lut_gemm(jnp.array(x, dtype=jnp.float32), ew)
    np.testing.assert_allclose(np.array(got), want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 30),
    n=st.integers(1, 12),
    qbits=st.sampled_from([(2, 2), (3, 3), (4, 4), (2, 4), (4, 2)]),
    seed=st.integers(0, 2**31 - 1),
    tile=st.sampled_from([8, 32, 128]),
)
def test_pallas_hypothesis_sweep(m, k, n, qbits, seed, tile):
    qx, qw = 1 << qbits[0], 1 << qbits[1]
    rng = np.random.default_rng(seed)
    x, w, lut = random_case(rng, m, k, n, qx, qw)
    want = ref.lut_gemm_ref(x, w, lut)
    ew = lk.build_ew(jnp.array(lut), jnp.array(w, dtype=jnp.float32))
    got = lk.lut_gemm(jnp.array(x, dtype=jnp.float32), ew, tile_m=tile)
    np.testing.assert_allclose(np.array(got), want, rtol=1e-4, atol=1e-4)


def test_convenience_wrapper():
    rng = np.random.default_rng(7)
    x, w, lut = random_case(rng, 6, 4, 3, 8, 8)
    want = ref.lut_gemm_ref(x, w, lut)
    got = lk.lut_gemm_from_codes(
        jnp.array(x, jnp.float32), jnp.array(w, jnp.float32), jnp.array(lut))
    np.testing.assert_allclose(np.array(got), want, rtol=1e-5, atol=1e-5)


def test_exact_multiplier_lut_reproduces_int_gemm():
    """With LUT[a,b] = a·b the LUT-GEMM must equal the plain integer GEMM."""
    rng = np.random.default_rng(3)
    qx = qw = 16
    x = rng.integers(0, qx, size=(12, 9))
    w = rng.integers(0, qw, size=(9, 5))
    lut = np.outer(np.arange(qx), np.arange(qw)).astype(np.float32)
    got = lk.lut_gemm_from_codes(
        jnp.array(x, jnp.float32), jnp.array(w, jnp.float32), jnp.array(lut))
    np.testing.assert_allclose(np.array(got), (x @ w).astype(np.float64), rtol=1e-5)


def test_vmem_estimate_within_tpu_budget():
    """DESIGN §Perf: worst model-zoo tile fits a 16 MiB VMEM."""
    worst = max(
        lk.vmem_bytes_estimate(k=288, q=16, n=32),   # biggest 4-bit layer
        lk.vmem_bytes_estimate(k=72, q=256, n=8),    # biggest 8-bit layer
    )
    assert worst < 16 * 1024 * 1024
